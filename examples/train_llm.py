"""End-to-end driver (deliverable b): train a ~100M-parameter LM with the full
framework — scheduler bins, timer database, AdaptCheck-steered checkpointing,
async writer, restartability, straggler detector, timing report.

Default config is a ~100M llama-style model on the copy task (loss visibly
drops as induction forms).  A full run on this CPU container:

    PYTHONPATH=src python examples/train_llm.py --steps 300

is slow (~1 TFLOP/step); ``--fast`` scales to a ~20M model / smaller batch for
a few-minute demonstration with identical code paths.
"""

from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro import timing  # noqa: E402
from repro.launch.train import TrainSettings, run_training  # noqa: E402
from repro.models.config import ArchConfig  # noqa: E402


def model_100m() -> ArchConfig:
    return ArchConfig(
        name="demo-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=16384,
        rope_theta=10000.0, attn_chunk=128,
    )


def model_20m() -> ArchConfig:
    return ArchConfig(
        name="demo-20m", family="dense", n_layers=6, d_model=320,
        n_heads=5, n_kv_heads=5, d_ff=1280, vocab_size=8192,
        rope_theta=10000.0, attn_chunk=128,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fast", action="store_true", help="~20M model, small batch")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/train_llm_ckpt")
    ap.add_argument("--monitor-port", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = model_20m() if args.fast else model_100m()
    batch = args.batch or (4 if args.fast else 8)
    seq = args.seq or (128 if args.fast else 256)
    settings = TrainSettings(
        arch=cfg.name, steps=args.steps, global_batch=batch, seq_len=seq,
        peak_lr=3e-3, ckpt_dir=args.ckpt_dir, ckpt_mode="adaptive",
        ckpt_max_fraction=0.05, ckpt_max_interval_s=120.0,
        report_every=20, data_mode="copy", monitor_port=args.monitor_port,
        log_path=args.ckpt_dir + "/timers.jsonl",
    )
    sess = timing.TimingSession(timing.timer_db())
    summary = run_training(settings, cfg=cfg, session=sess)
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("bin_seconds", "timer_tree")},
                     indent=1, default=str))
    print(sess.report(channels=("walltime", "cputime", "xla_flops")))
    print()
    print(sess.tree_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
