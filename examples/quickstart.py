"""Quickstart: the timing infrastructure in 60 lines.

Shows the ``repro.timing`` facade end to end: a session bundling the timing
stack, hierarchical scopes (dynamic and pre-resolved handles), scope-local
counters, a custom clock (the paper's extension mechanism), a scheduled loop
that gets caliper points for free, and both reports — the flat Fig.-2 table
and the scope tree with inclusive/exclusive seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import timing
from repro.core import CallbackClock, RunState, register_clock

# --- 1. a session: DB + scheduler + control loop, installed as the default ----
with timing.session() as ts:
    # --- 2. hierarchical scopes (paper Table 3, path-addressed) ---------------
    with timing.scope("poisson"):
        with timing.scope("residual"):              # timer "poisson/residual"
            x = jnp.ones((512, 512))
            jax.block_until_ready(x @ x)
    print("manual scope:", ts.timer("poisson/residual").seconds(), "s\n")

    # hot-loop form: resolve the path once, enter with zero dict lookups
    hot = timing.scope_handle("poisson/hot_loop")

    # --- 3. extensibility: register a custom event clock ----------------------
    _steps = [0.0]
    register_clock(
        "steps",
        lambda: CallbackClock(
            "steps", lambda: {"steps_done": _steps[0]}, {"steps_done": "count"}
        ),
    )

    # counter: resolved once; bumps the process-global xla_flops channel
    bump_flops = timing.counter("xla_flops", absolute=True)

    # --- 4. scheduled loop: every routine gets scoped timers automatically ----
    def evolve(state: RunState) -> None:
        with hot:                                   # nests under EVOL/demo::evolve
            y = jnp.sin(jnp.arange(4096.0))
            jax.block_until_ready(y)
        _steps[0] += 1
        bump_flops(4096.0)

    def analysis(state: RunState) -> None:
        time.sleep(0.001)

    ts.scheduler.schedule(evolve, bin="EVOL", thorn="demo")
    ts.scheduler.schedule(analysis, bin="ANALYSIS", thorn="demo", every=2)
    ts.scheduler.run(RunState(max_iterations=6))

    # --- 5. the reports: flat Fig.-2 table + the scope tree --------------------
    print(ts.report(channels=("walltime", "cputime", "xla_flops", "steps_done")))
    print()
    print(ts.tree_report())
    print("\nEVOL rollup (segment-matched):", timing.total_seconds("bin/EVOL"), "s")
