"""Quickstart: the timing infrastructure in 60 lines.

Creates timers/clocks (paper Table 3 usage pattern), registers a custom clock
(the extension mechanism), runs a tiny scheduled loop, and prints the Fig-2
style report.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (
    CallbackClock,
    RunState,
    Scheduler,
    format_report,
    register_clock,
    timer_db,
)
from repro.core.clocks import counter_cell

# --- 1. manual caliper points (paper Table 3) --------------------------------
db = timer_db()
handle = db.create("Poisson: Evaluate residual")   # CCTK_TimerCreate
db.start(handle)                                   # CCTK_TimerStartI
x = jnp.ones((512, 512))
jax.block_until_ready(x @ x)
db.stop(handle)                                    # CCTK_TimerStopI
print("manual timer:", db.get(handle).read_flat()["walltime"], "s\n")

# --- 2. extensibility: register a custom event clock --------------------------
register_clock(
    "steps",
    lambda: CallbackClock("steps", lambda: {"steps_done": _steps[0]}, {"steps_done": "count"}),
)
_steps = [0.0]

# --- 3. scheduled loop: every routine gets timers automatically ----------------
sch = Scheduler(db)


# hot-loop counter: resolve the channel once, bump with one C-level call
bump_flops = counter_cell("xla_flops")


def evolve(state: RunState) -> None:
    y = jnp.sin(jnp.arange(4096.0))
    jax.block_until_ready(y)
    _steps[0] += 1
    bump_flops(4096.0)


def analysis(state: RunState) -> None:
    time.sleep(0.001)


sch.schedule(evolve, bin="EVOL", thorn="demo")
sch.schedule(analysis, bin="ANALYSIS", thorn="demo", every=2)
sch.run(RunState(max_iterations=6))

# --- 4. the standard report (paper Fig. 2) -------------------------------------
print(format_report(db, channels=("walltime", "cputime", "xla_flops", "steps_done")))
