"""Serving example (deliverable b): batched requests through the ServingEngine
with the timing infrastructure and latency-steered batch size (paper §3.3).

    PYTHONPATH=src python examples/serve_llm.py --requests 24 --target-ms 50
"""

from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import timing  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serving import Request, ServingEngine  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--target-ms", type=float, default=None,
                    help="decode latency target; enables self-steering")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sess = timing.session()
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch,
        max_seq=args.prompt_len + args.max_new + 8,
        target_decode_ms=args.target_ms,
        session=sess,
    )
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid, prompt=list(rng.integers(0, cfg.vocab_size, args.prompt_len)),
            max_new_tokens=args.max_new,
        ))
    engine.run()
    print(json.dumps(engine.stats(), indent=1))
    print(sess.report())
    print()
    print(sess.tree_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
