"""Serving example (deliverable b): continuous batching through ServeSession
with every steering/shed decision on the adapt control plane (paper §3.3).

    PYTHONPATH=src python examples/serve_llm.py --requests 24 --target-ms 50
"""

from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import timing  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serving import Request, ServeSession, ServiceLevel  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--target-ms", type=float, default=None,
                    help="decode-step latency target; enables ADAPT/serving steering")
    ap.add_argument("--max-queue-delay", type=float, default=None,
                    help="shed queued requests past this estimated wait (s)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with timing.session() as sess:
        engine = ServeSession(
            cfg, params,
            session=sess,
            n_slots=args.slots,
            max_seq=args.prompt_len + args.max_new + 8,
            slo=ServiceLevel(target_decode_ms=args.target_ms,
                             max_queue_delay_s=args.max_queue_delay),
        )
        rng = np.random.default_rng(0)
        handles = [
            engine.submit(Request(
                rid, prompt=list(rng.integers(0, cfg.vocab_size, args.prompt_len)),
                max_new_tokens=args.max_new,
            ))
            for rid in range(args.requests)
        ]
        engine.run_until_idle()
        print(f"done: {sum(h.done for h in handles)}/{len(handles)} handles resolved")
        print(json.dumps(engine.stats(), indent=1))
        print(sess.report())
        print()
        print(sess.tree_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
