"""Paper §4 reproduction: adaptive checkpointing on an AMR-style workload.

The paper's experiment: the Ccatie/Carpet AMR run starts on a 40³ grid and
adds one refinement level every N iterations, so compute per iteration grows
O(2^L) (finer levels subcycle) while checkpoint data grows O(L).  With
fixed-interval checkpointing the run spends 19% of wall time checkpointing;
bounding the fraction at 5% with AdaptCheck holds the bound and cuts total
runtime ~17%.

This example reproduces that shape faithfully in JAX: a 3D wave-equation
(finite-difference) solver on a growing level hierarchy, checkpointed through
the real CheckpointManager, scheduled through the real scheduler + timer
database, and steered by the real AdaptiveCheckpointController.  Run:

    PYTHONPATH=src python examples/amr_adaptive_checkpoint.py            # both runs
    PYTHONPATH=src python examples/amr_adaptive_checkpoint.py --mode fixed
    PYTHONPATH=src python examples/amr_adaptive_checkpoint.py --mode adaptive

The benchmark harness (benchmarks/bench_adaptive_checkpoint.py) imports
``run_experiment`` and asserts the paper's claims (bound held, double-digit
runtime cut).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")  # allow running from the repo root without install

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.core import (  # noqa: E402
    AdaptiveCheckpointController,
    AdaptiveCheckpointPolicy,
    RunState,
)
from repro.timing import TimingSession  # noqa: E402


@dataclass
class AMRSettings:
    mode: str = "adaptive"             # "fixed" | "adaptive" | "interval"
    iterations: int = 120
    grid: int = 48                     # per-level grid (paper: 40³)
    substeps: int = 10                 # leapfrog steps per (level-)iteration
    max_levels: int = 4
    regrid_every: int = 30             # paper: 5120
    fixed_every: int = 8               # paper: 512 (scaled to iteration count)
    max_fraction: float = 0.05         # paper's 5% bound
    max_interval_s: float = 3.0        # "interval" mode bound (paper §4 last run)
    ckpt_dir: str = "/tmp/amr_ckpt"
    ckpt_delay_s: float = 0.01         # emulated filesystem latency per write
    ckpt_delay_s_per_mb: float = 0.02  # + size-proportional cost (O(L) data)
    seed: int = 0


def _make_level(grid: int, key) -> dict[str, jax.Array]:
    u = 0.1 * jax.random.normal(key, (grid, grid, grid), jnp.float32)
    return {"u": u, "v": jnp.zeros_like(u)}


@jax.jit
def _wave_step(level: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """Leapfrog step of the 3D wave equation with a 7-point Laplacian."""
    u, v = level["u"], level["v"]
    lap = (
        jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
        + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1)
        + jnp.roll(u, 1, 2) + jnp.roll(u, -1, 2)
        - 6.0 * u
    )
    v = v + 0.1 * lap
    u = u + 0.1 * v
    return {"u": u, "v": v}


def run_experiment(settings: AMRSettings) -> dict[str, object]:
    # a fresh session per experiment run: no global-DB juggling, and the two
    # modes of the paper's A/B never share timers
    sess = TimingSession()
    sch = sess.scheduler
    st = RunState(max_iterations=settings.iterations)

    manager = CheckpointManager(
        settings.ckpt_dir + f"_{settings.mode}", synchronous=True,
        fsync=False, delay_s=settings.ckpt_delay_s,
        delay_s_per_mb=settings.ckpt_delay_s_per_mb, keep_n=2,
    )
    if settings.mode == "interval":
        # paper §4 second experiment: bound only the wall-time interval between
        # checkpoints — the fraction bound is set ≈0 so nothing else admits
        policy = AdaptiveCheckpointPolicy(
            mode="adaptive", max_fraction=1e-9,
            max_interval_seconds=settings.max_interval_s, use_predictor=True,
        )
    else:
        policy = AdaptiveCheckpointPolicy(
            mode="fixed" if settings.mode == "fixed" else "adaptive",
            every_iterations=settings.fixed_every,
            max_fraction=settings.max_fraction if settings.mode == "adaptive" else 1.0,
            max_interval_seconds=1e9,
            use_predictor=settings.mode != "fixed",
        )
    controller = AdaptiveCheckpointController(policy)
    fraction_trace: list[dict[str, float]] = []

    def startup(s: RunState) -> None:
        key = jax.random.PRNGKey(settings.seed)
        s["levels"] = [_make_level(settings.grid, key)]
        # warm the jit cache so compile time is not attributed to the loop
        jax.block_until_ready(_wave_step(s["levels"][0]))
        controller.start_run(time.monotonic())

    sch.schedule(startup, bin="STARTUP", thorn="amr")

    def maybe_regrid(s: RunState) -> None:
        """Add a refinement level every `regrid_every` iterations (paper: the
        collapse drives new levels; data grows O(L), compute grows O(2^L))."""
        want = min(1 + s.iteration // settings.regrid_every, settings.max_levels)
        while len(s["levels"]) < want:
            key = jax.random.PRNGKey(settings.seed + len(s["levels"]))
            s["levels"] = s["levels"] + [_make_level(settings.grid, key)]

    sch.schedule(maybe_regrid, bin="PRESTEP", thorn="carpet")

    def evolve(s: RunState) -> None:
        new_levels = []
        for l, level in enumerate(s["levels"]):
            # subcycling: finer levels take 2^l sub-iterations
            for _ in range(settings.substeps * 2 ** l):
                level = _wave_step(level)
            new_levels.append(jax.tree.map(jax.block_until_ready, level))
        s["levels"] = new_levels

    sch.schedule(evolve, bin="EVOL", thorn="ccatie")

    ckpt_timer = "CHECKPOINT/adaptcheck::write"
    ckpt_scope = sess.scope_handle(ckpt_timer)

    def checkpoint(s: RunState) -> None:
        now = time.monotonic()
        total = now - controller.started_at
        spent = ckpt_scope.seconds()
        nbytes_next = sum(
            int(np.prod(x.shape)) * 4 for lv in s["levels"] for x in jax.tree.leaves(lv)
        )
        decision = controller.decide(
            iteration=s.iteration, now=now, total_seconds=total,
            checkpoint_seconds=spent, next_checkpoint_bytes=nbytes_next,
        )
        fraction_trace.append(
            {"iteration": s.iteration, "fraction": decision.fraction,
             "levels": len(s["levels"]), "checkpointed": float(decision.checkpoint)}
        )
        if not decision.checkpoint:
            return
        with ckpt_scope:
            stats = manager.save(s.iteration, {"levels": s["levels"]})
        controller.observe_checkpoint(time.monotonic(), stats["blocking_seconds"],
                                      stats["nbytes"])

    sch.schedule(checkpoint, bin="CHECKPOINT", thorn="adaptcheck")

    def shutdown(s: RunState) -> None:
        manager.close()

    sch.schedule(shutdown, bin="SHUTDOWN", thorn="amr")

    with sess:
        sch.run(st)

    # loop wall time (excludes STARTUP, matching the controller's accounting)
    total = time.monotonic() - controller.started_at
    ckpt = ckpt_scope.seconds()
    return {
        "mode": settings.mode,
        "iterations": st.iteration,
        "total_seconds": total,
        "checkpoint_seconds": ckpt,
        "checkpoint_fraction": ckpt / total if total else 0.0,
        "n_checkpoints": controller.n_checkpoints,
        "n_suppressed": controller.n_suppressed,
        "final_levels": len(st["levels"]),
        "fraction_trace": fraction_trace,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["fixed", "adaptive", "interval", "both"],
                    default="both")
    ap.add_argument("--iterations", type=int, default=120)
    ap.add_argument("--ckpt-delay", type=float, default=0.05)
    args = ap.parse_args(argv)

    modes = ["fixed", "adaptive"] if args.mode == "both" else [args.mode]
    results = {}
    for mode in modes:
        res = run_experiment(AMRSettings(mode=mode, iterations=args.iterations))
        res_small = {k: v for k, v in res.items() if k != "fraction_trace"}
        print(f"[amr:{mode}] {json.dumps(res_small, indent=1)}")
        results[mode] = res
    if len(results) == 2:
        f, a = results["fixed"], results["adaptive"]
        cut = 1.0 - a["total_seconds"] / f["total_seconds"]
        print(f"\n[amr] fixed:    {f['checkpoint_fraction']:.1%} of wall time checkpointing")
        print(f"[amr] adaptive: {a['checkpoint_fraction']:.1%} of wall time checkpointing "
              f"(bound 5%)")
        print(f"[amr] total runtime cut: {cut:.1%} (paper: ~17%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
