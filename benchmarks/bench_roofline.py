"""Roofline mini dry-run: the bench-smoke rows gating the analysis pipeline.

Runs a reduced (arch × shape) dry-run matrix — SMOKE_CONFIGs on a forced
8-host-device 2×4 ("data","model") mesh — in a subprocess (jax locks the
device count at first init, so the forced topology must not leak into the
parent), then pushes the artifacts through ``benchmarks.roofline`` exactly as
the full 512-device matrix would be.  Two rows per cell:

    roofline/<arch>/<shape>/bound_us   perfect-overlap step lower bound
                                       (max of compute/memory/collective)
    roofline/<arch>/<shape>/gap        bound / ideal-model-compute time
                                       (dimensionless; 1.0 = at the roofline)

Unlike the timed benches these are *deterministic* — derived from compiled
HLO cost analysis, not wall time — so the CI gate runs them once (no
min-of-3) and any ratio drift against the committed baseline means the
lowered computation itself changed shape (flops, bytes, or collectives).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time

_MINI_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
out_dir = sys.argv[1]

from repro.dist.compat import make_mesh
from repro.launch.dryrun import run_cell
from repro.models.config import ShapeConfig

mesh = make_mesh((2, 4), ("data", "model"), auto_axis_types=True)
train = ShapeConfig("mini_train", "train", 128, 8)
decode = ShapeConfig("mini_decode", "decode", 256, 8)
cells = [
    ("llama3.2-1b", train),          # dense attention, tied embeddings
    ("recurrentgemma-9b", train),    # hybrid rglru + local-attention pattern
    ("llama3.2-1b", decode),         # memory-bound cell (cache + params)
]
for arch, shape in cells:
    run_cell(arch, shape.name, False, out_dir=out_dir, smoke=True,
             mesh=mesh, mesh_label="mini", shape_override=shape)
print("ROOFLINE_MINI_OK")
"""


def run(scale: float = 1.0) -> list[tuple[str, float, str]]:
    from . import roofline

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    with tempfile.TemporaryDirectory(prefix="roofline_mini_") as out_dir:
        proc = subprocess.run(
            [sys.executable, "-c", _MINI_SCRIPT, out_dir],
            capture_output=True, text=True, timeout=1800, env=env, cwd=repo,
        )
        if proc.returncode != 0 or "ROOFLINE_MINI_OK" not in proc.stdout:
            raise RuntimeError(
                f"mini dry-run failed (rc={proc.returncode}):\n{proc.stderr}"
            )
        rows_out: list[tuple[str, float, str]] = []
        for r in sorted(
            roofline.load_rows(out_dir, mesh="mini"),
            key=lambda r: (r.arch, r.shape),
        ):
            bound_us = r.step_seconds_lower_bound * 1e6
            ideal_us = r.model_flops_per_dev / roofline.PEAK_FLOPS * 1e6
            gap = bound_us / ideal_us if ideal_us > 0 else 0.0
            rows_out.append(
                (f"roofline/{r.arch}/{r.shape}/bound_us", bound_us,
                 f"dominant={r.dominant}")
            )
            rows_out.append(
                (f"roofline/{r.arch}/{r.shape}/gap", gap, "bound_over_ideal")
            )
        if not rows_out:
            raise RuntimeError("mini dry-run produced no roofline rows")
    return rows_out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Roofline rows from a mini 8-device dry-run (CI gate)."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="unused (deterministic bench); kept for harness symmetry")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if args.json:
        payload = {
            "bench": "roofline",
            "scale": args.scale,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [
                {"name": name, "us_per_call": value, "derived": derived}
                for name, value, derived in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
