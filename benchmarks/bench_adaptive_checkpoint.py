"""Paper Fig. 3 / §4 reproduction: fixed-interval vs AdaptCheck checkpointing
on the AMR-style workload, asserting the paper's claims:

  * the adaptive run keeps the checkpoint fraction within the 5% bound
    (paper Fig. 3 left);
  * total checkpoint time drops by an order of magnitude vs fixed-interval
    (paper: 319s -> 75s with the interval bound);
  * total runtime is cut by a double-digit percentage (paper: ~17-20%).

Also measures the beyond-paper async-writer win (blocking seconds per save,
sync vs async) — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))

from amr_adaptive_checkpoint import AMRSettings, run_experiment  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402


def run(iterations: int = 90) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    fixed = run_experiment(AMRSettings(mode="fixed", iterations=iterations))
    adaptive = run_experiment(AMRSettings(mode="adaptive", iterations=iterations))

    rows.append(("amr_fixed/ckpt_fraction", 100 * fixed["checkpoint_fraction"], "percent"))
    rows.append(("amr_adaptive/ckpt_fraction", 100 * adaptive["checkpoint_fraction"], "percent"))
    rows.append(("amr_fixed/ckpt_seconds", fixed["checkpoint_seconds"] * 1e6, "us_total"))
    rows.append(("amr_adaptive/ckpt_seconds", adaptive["checkpoint_seconds"] * 1e6, "us_total"))
    rows.append(("amr_fixed/total_seconds", fixed["total_seconds"] * 1e6, "us_total"))
    rows.append(("amr_adaptive/total_seconds", adaptive["total_seconds"] * 1e6, "us_total"))
    cut = 1.0 - adaptive["total_seconds"] / fixed["total_seconds"]
    rows.append(("amr_runtime_cut", 100 * cut, "percent"))
    rows.append(("amr_adaptive/n_checkpoints", float(adaptive["n_checkpoints"]), "count"))
    rows.append(("amr_fixed/n_checkpoints", float(fixed["n_checkpoints"]), "count"))

    # paper-claim checks (weak bound: small overshoot from the final ckpt ok)
    assert adaptive["checkpoint_fraction"] <= 0.08, adaptive["checkpoint_fraction"]
    assert adaptive["checkpoint_seconds"] < 0.5 * fixed["checkpoint_seconds"]
    assert cut > 0.05, f"runtime cut only {cut:.1%}"

    # paper §4 second experiment: interval-bound-only mode (319s -> 75s, ~4.3x)
    interval = run_experiment(AMRSettings(mode="interval", iterations=iterations,
                                          max_interval_s=2.0))
    rows.append(("amr_interval/ckpt_seconds", interval["checkpoint_seconds"] * 1e6, "us_total"))
    rows.append((
        "amr_interval/ckpt_cut_vs_fixed",
        fixed["checkpoint_seconds"] / max(interval["checkpoint_seconds"], 1e-9), "x",
    ))
    assert interval["checkpoint_seconds"] < 0.5 * fixed["checkpoint_seconds"]

    # beyond-paper: async blocking time vs sync write time
    big = {"x": np.zeros((1 << 21,), np.float32)}  # 8 MB
    sync = CheckpointManager("/tmp/bench_ck_sync", synchronous=True, delay_s=0.1)
    s1 = sync.save(0, big); sync.close()
    asy = CheckpointManager("/tmp/bench_ck_async", synchronous=False, delay_s=0.1)
    s2 = asy.save(0, big); asy.close()
    rows.append(("ckpt_blocking/sync", s1["blocking_seconds"] * 1e6, "us_per_save"))
    rows.append(("ckpt_blocking/async", s2["blocking_seconds"] * 1e6, "us_per_save"))
    rows.append(
        ("ckpt_blocking/async_speedup",
         s1["blocking_seconds"] / max(s2["blocking_seconds"], 1e-9), "x")
    )
    return rows
