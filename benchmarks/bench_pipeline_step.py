"""1F1B pipeline-step overheads: the schedule rows the CI perf gate pins.

Measures us per operation for the fused 1F1B dispatch (one jitted tick loop:
loss + per-stage grads), the phase-split dispatch (warmup/steady/cooldown as
three synchronized segments — the launcher's timed path; the delta against
the fused row is the price of per-phase timing), and the StagePlan
pack/unpack round trip (the restage actuator's per-step cost).

Methodology matches bench_clock_overhead: each row is the best of ``repeats``
timed loops after a warmup call (jit tracing excluded), run on a 1-device
``pod`` mesh so CI needs no forced topology; ``--scale`` shrinks iteration
counts for smoke runs.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def _time_op(fn, n: int, scale: float = 1.0, repeats: int = 3) -> float:
    n = max(int(n * scale), 3)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e6


def run(scale: float = 1.0) -> list[tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp

    from repro.dist.meshutil import local_mesh
    from repro.dist.pipeline import PipelineStep, StagePlan

    width, n_layers, n_micro, micro_batch = 16, 4, 4, 2
    mesh = local_mesh((1,), ("pod",))
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    layers = jax.random.normal(k1, (n_layers, 2, width, width)) * 0.3
    x = jax.random.normal(k2, (n_micro * micro_batch, width))
    tgt = jax.random.normal(k3, (n_micro * micro_batch, width))

    def layer_fn(w, a):
        return a + jnp.tanh(a @ w[0]) @ w[1] * 0.1

    def loss_fn(y, t):
        return jnp.mean((y - t) ** 2)

    rows: list[tuple[str, float, str]] = []

    fused = PipelineStep(layer_fn, loss_fn, mesh=mesh, axis="pod", n_micro=n_micro)

    def fused_step():
        loss, grads = fused(layers, x, tgt)
        jax.block_until_ready(grads)

    fused_step()  # trace + compile outside the timed region
    rows.append(("pipeline_step/fused", _time_op(fused_step, 60, scale), "us_per_step"))

    class _NoopPhase:
        def __enter__(self):
            return None

        def __exit__(self, *exc):
            return False

    phased = PipelineStep(
        layer_fn, loss_fn, mesh=mesh, axis="pod", n_micro=n_micro,
        phase_cb=lambda name: _NoopPhase(),
    )

    def phased_step():
        loss, grads = phased(layers, x, tgt)
        jax.block_until_ready(grads)

    phased_step()
    rows.append(("pipeline_step/phased", _time_op(phased_step, 60, scale), "us_per_step"))

    plan = StagePlan(n_layers=n_layers, weights={0: 2.0, 1: 1.0})

    def repack():
        packed, mask = plan.pack(layers)
        jax.block_until_ready(plan.unpack(packed))

    repack()
    rows.append(("stage_plan_pack_unpack", _time_op(repack, 200, scale), "us_per_call"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="1F1B pipeline schedule overheads (CI perf-gate rows)."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="iteration-count multiplier (CI smoke: 0.5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if args.json:
        payload = {
            "bench": "pipeline_step",
            "scale": args.scale,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [
                {"name": name, "us_per_call": value, "derived": derived}
                for name, value, derived in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
