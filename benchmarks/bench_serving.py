"""Continuous vs static batching under an open-loop load generator.

The headline row pair the CI perf gate pins relationally: on the same
open-loop trace (Poisson arrivals, heavy-tailed bucketed prompt/output
lengths), :class:`repro.serving.ServeSession` run continuously must serve a
token at least as cheaply as the same engine driven on a **static-batch
schedule** (admit up to ``max_batch``, decode until the whole batch drains,
only then admit again — the schedule the removed ``ServingEngine`` shim
implemented, now expressed as a driving policy over the one supported
engine, so the comparison isolates the *schedule* with identical kernels) —
``serving/continuous_us_per_token <= serving/static_us_per_token``.
Heavy-tailed *output* lengths are where the schedules diverge: the static
schedule decodes a batch until its longest request finishes (short
batch-mates occupy rows doing nothing), while the continuous schedule frees
a slot the moment a request completes and splices the next prefill in
mid-stream.

Methodology follows the other benches: the load generator is open-loop (the
trace fires on the wall clock regardless of completions — the arrival shape
production SLOs are judged under; the default rate saturates the engines so
the measurement is service throughput, not arrival idling), prompt lengths
are quantized to buckets so every jit shape is compiled during the untimed
warmup drain, and each row is measured over one timed drain of the same
seeded trace through both engines.  ``--scale`` shrinks the trace for CI
smoke runs; p95 latency rows ride along for the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from dataclasses import dataclass

PROMPT_BUCKETS = (16, 32)


@dataclass(frozen=True)
class TraceItem:
    at: float  # arrival offset from trace start, seconds
    prompt: list[int]
    max_new: int


def make_trace(
    n: int, vocab_size: int, *, arrival_rate: float, seed: int = 0
) -> list[TraceItem]:
    """Open-loop trace: Poisson arrivals, lognormal (heavy-tail) lengths.

    Prompt lengths are quantized to ``PROMPT_BUCKETS`` so the prefill shape
    set is closed (both engines compile every shape in warmup); output
    lengths keep their heavy tail — that is the workload property continuous
    batching exploits.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    at = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    raw_plen = rng.lognormal(mean=3.0, sigma=0.6, size=n)
    # output lengths: heavy tail clipped to the decode budget — a batch that
    # mixes a 32-token request with 2-token ones is where the static schedule
    # strands slots and the continuous one refills them
    out = np.clip(np.round(rng.lognormal(mean=2.0, sigma=1.0, size=n)), 1, 32)
    items = []
    for i in range(n):
        plen = min(PROMPT_BUCKETS, key=lambda b: abs(b - raw_plen[i]))
        items.append(TraceItem(
            at=float(at[i]),
            prompt=[int(t) for t in rng.integers(0, vocab_size, plen)],
            max_new=int(out[i]),
        ))
    return items


def _submit(engine, item: TraceItem, rid: int):
    from repro.serving import Request

    return engine.submit(Request(rid, list(item.prompt), max_new_tokens=item.max_new))


def _drive(engine, step, idle, trace: list[TraceItem], rid0: int) -> float:
    """Replay the trace open-loop against the wall clock; returns drain time."""
    pending = list(trace)
    t0 = time.perf_counter()
    rid = rid0
    while pending or not idle():
        now = time.perf_counter() - t0
        while pending and pending[0].at <= now:
            _submit(engine, pending.pop(0), rid)
            rid += 1
        if idle():
            time.sleep(min(max(pending[0].at - now, 0.0), 1e-3))
            continue
        step()
    return time.perf_counter() - t0


def run(scale: float = 1.0, arrival_rate: float = 500.0, seed: int = 0):
    import jax

    from repro.configs import get_smoke_config
    from repro.core.timers import TimerDB
    from repro.models import model as M
    from repro.serving import ServeSession

    n_requests = max(int(32 * scale) // 4 * 4, 8)
    max_batch = n_slots = 4
    max_seq = max(PROMPT_BUCKETS) + 40
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    trace = make_trace(n_requests, cfg.vocab_size, arrival_rate=arrival_rate, seed=seed)
    # warmup trace: one full batch per prompt bucket compiles every prefill
    # shape each engine will see ((1, bucket) continuous, (max_batch, bucket)
    # static), plus both decode shapes and the splice
    warm = [
        TraceItem(0.0, [1] * bucket, 2)
        for bucket in PROMPT_BUCKETS
        for _ in range(max_batch)
    ]

    rows: list[tuple[str, float]] = []

    continuous = ServeSession(
        cfg, params, n_slots=n_slots, max_seq=max_seq, db=TimerDB(), control=False
    )
    c_idle = lambda: not continuous.queue_depth and not continuous.active_slots  # noqa: E731
    _drive(continuous, continuous.step, c_idle, warm, rid0=10_000)
    n_warm = len(continuous.completed)
    elapsed = _drive(continuous, continuous.step, c_idle, trace, rid0=0)
    timed = continuous.completed[n_warm:]
    tokens = sum(len(r.tokens) for r in timed)
    lat = sorted(r.latency_s for r in timed)
    rows.append(("serving/continuous_us_per_token", elapsed / tokens * 1e6))
    rows.append(("serving/continuous_p95_latency_us", lat[int(0.95 * (len(lat) - 1))] * 1e6))

    # The static schedule only admits at batch boundaries, so an open-loop
    # replay would merely randomize its batch sizes (and their jit shapes).
    # Closed-loop drain is its best case — always-full batches, the warmed
    # compile set — which keeps the continuous<=static gate conservative.
    # Same engine, batch-synchronous driver: admit up to max_batch, decode to
    # idle (the drain stall), only then admit the next batch.
    static = ServeSession(
        cfg, params, n_slots=max_batch, max_seq=max_seq, db=TimerDB(), control=False
    )

    def _static_drain(items: list[TraceItem], rid0: int) -> float:
        t0 = time.perf_counter()
        for start in range(0, len(items), max_batch):
            for offset, item in enumerate(items[start : start + max_batch]):
                _submit(static, item, rid0 + start + offset)
            static.run_until_idle()
        return time.perf_counter() - t0

    _static_drain(warm, 10_000)
    n_warm = len(static.completed)
    elapsed = _static_drain(trace, 0)
    timed = static.completed[n_warm:]
    tokens = sum(len(r.tokens) for r in timed)
    lat = sorted(r.latency_s for r in timed)
    rows.append(("serving/static_us_per_token", elapsed / tokens * 1e6))
    rows.append(("serving/static_p95_latency_us", lat[int(0.95 * (len(lat) - 1))] * 1e6))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Continuous vs static batching on one open-loop trace."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="trace-size multiplier (CI smoke: 0.5)")
    ap.add_argument("--arrival-rate", type=float, default=500.0,
                    help="open-loop Poisson arrivals per second (default "
                         "saturates: measures service throughput)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale, arrival_rate=args.arrival_rate, seed=args.seed)
    print("name,us_per_call")
    for name, value in rows:
        print(f"{name},{value:.3f}")
    if args.json:
        payload = {
            "bench": "serving",
            "scale": args.scale,
            "arrival_rate": args.arrival_rate,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [{"name": name, "us_per_call": value} for name, value in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
