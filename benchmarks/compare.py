"""Perf-regression gate: compare two bench JSONs row by row.

Used by the CI bench-smoke job to compare a freshly measured
``BENCH_clock_overhead.json`` against the committed baseline under
``benchmarks/baselines/``; exits non-zero when any matched row slowed down by
more than ``--max-ratio``.

Rows are matched by name.  Sub-resolution rows (both sides below ``--min-us``)
are ignored — micro-benchmark noise at those magnitudes is not a regression
signal.  Rows present in only one file are reported but do not fail the gate
(benches gain and rename rows across PRs); the gate's teeth are on the rows
both sides know about.

Relational gates: ``--require-le ROW REF RATIO`` (repeatable) additionally
fails when the fresh measurement of ``ROW`` exceeds ``RATIO`` x the fresh
measurement of ``REF`` — used to pin an API's hot path to the primitive it
wraps (e.g. ``scope_handle_enter_exit`` vs ``timer_start_stop_all_clocks``)
independent of container drift, since both sides come from the same run.

Several fresh JSONs may be passed; each row gates on its *minimum* across
them.  A real regression slows every run, while scheduler noise on a shared
runner inflates individual runs at random — min-of-N is the standard
microbenchmark noise filter (the bench itself already takes best-of-repeats
within a run; this extends it across process launches).

    python -m benchmarks.compare benchmarks/baselines/clock_overhead.json \
        BENCH_1.json BENCH_2.json BENCH_3.json --max-ratio 2.0

Re-baselining from CI instead of the committed container numbers:

* ``--emit-baseline OUT`` writes the merged per-row minimum of the fresh runs
  as a baseline-shaped JSON.  The CI bench-smoke job emits and uploads this as
  the canonical re-baseline artifact, measured on the *actual runner fleet*.
* ``--baseline-from-artifact PATH`` reads the baseline from a downloaded CI
  artifact — a JSON file or a directory containing one (as
  ``actions/download-artifact`` produces).  Pass ``-`` as the positional
  baseline so no fresh run is mistaken for it::

      python -m benchmarks.compare - BENCH_1.json BENCH_2.json \
          --baseline-from-artifact ./artifact-dir

  To re-baseline permanently, commit the artifact's
  ``BENCH_baseline_candidate.json`` over ``benchmarks/baselines/``.
  ``- BENCH_*.json --emit-baseline OUT`` (no artifact) emits without gating.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {row["name"]: float(row["us_per_call"]) for row in payload["rows"]}


def compare(
    base: dict[str, float],
    fresh: dict[str, float],
    max_ratio: float = 2.0,
    min_us: float = 0.05,
) -> int:
    """Print the comparison table; return the number of failing rows."""
    failures = 0
    width = max([len(n) for n in {*base, *fresh}] + [len("row")]) + 2
    print(f"{'row'.ljust(width)} {'base_us':>12} {'new_us':>12} {'ratio':>8}  verdict")
    for name in sorted({*base, *fresh}):
        b, n = base.get(name), fresh.get(name)
        if b is None or n is None:
            which = "baseline" if b is None else "fresh run"
            print(f"{name.ljust(width)} {'-':>12} {'-':>12} {'-':>8}  SKIP (missing from {which})")
            continue
        if b < min_us and n < min_us:
            print(f"{name.ljust(width)} {b:12.3f} {n:12.3f} {'-':>8}  SKIP (below {min_us}us floor)")
            continue
        ratio = n / b if b > 0 else float("inf")
        verdict = "ok"
        if ratio > max_ratio:
            verdict = f"FAIL (> {max_ratio:g}x slowdown)"
            failures += 1
        print(f"{name.ljust(width)} {b:12.3f} {n:12.3f} {ratio:8.2f}  {verdict}")
    return failures


def check_relations(
    fresh: dict[str, float], relations: list[tuple[str, str, float]]
) -> int:
    """Gate fresh rows against each other; returns the number of failures."""
    failures = 0
    for row, ref, ratio in relations:
        a, b = fresh.get(row), fresh.get(ref)
        if a is None or b is None:
            missing = row if a is None else ref
            print(f"relation {row} <= {ratio:g}*{ref}: SKIP ({missing} not measured)")
            continue
        ok = a <= ratio * b
        print(
            f"relation {row} ({a:.3f}us) <= {ratio:g} * {ref} ({b:.3f}us)"
            f"  {'ok' if ok else 'FAIL'}"
        )
        if not ok:
            failures += 1
    return failures


def _min_rows(paths) -> dict[str, float]:
    """Per-row minimum across several fresh runs (noise filter)."""
    merged: dict[str, float] = {}
    for path in paths:
        for name, value in _load_rows(path).items():
            if name not in merged or value < merged[name]:
                merged[name] = value
    return merged


def _resolve_artifact(path: str) -> str:
    """A downloaded-artifact baseline: the JSON itself, or the directory
    ``actions/download-artifact`` unpacked it into."""
    if os.path.isdir(path):
        candidates = sorted(glob.glob(os.path.join(path, "BENCH_*.json"))) or sorted(
            glob.glob(os.path.join(path, "*.json"))
        )
        if not candidates:
            raise SystemExit(f"no baseline JSON found inside artifact dir {path!r}")
        return candidates[0]
    return path


def _emit_baseline(out_path: str, fresh_paths, merged: dict[str, float]) -> None:
    """Write the min-of-N merge as a baseline-shaped JSON (same schema the
    bench emits, so it can be committed over ``benchmarks/baselines/`` or fed
    back through ``--baseline-from-artifact`` unchanged)."""
    with open(fresh_paths[0]) as f:
        payload = json.load(f)
    payload["rows"] = [
        {"name": name, "us_per_call": merged[name]} for name in sorted(merged)
    ]
    payload["rebaseline"] = {"merged_from": len(fresh_paths), "filter": "per-row min"}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"re-baseline candidate ({len(merged)} rows) written to {out_path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("baseline",
                    help="committed baseline JSON, or '-' when the baseline "
                         "comes from --baseline-from-artifact (or for an "
                         "emit-only run); '-' keeps every following path a "
                         "fresh run — an optional positional would silently "
                         "swallow the first one")
    ap.add_argument("fresh", nargs="+",
                    help="freshly measured JSON(s); rows gate on their minimum")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when new/base exceeds this (default 2.0)")
    ap.add_argument("--min-us", type=float, default=0.05,
                    help="ignore rows where both sides are below this (noise floor)")
    ap.add_argument("--baseline-from-artifact", metavar="PATH", default=None,
                    help="baseline from a downloaded CI artifact (JSON file or "
                         "directory); pass '-' as the positional baseline")
    ap.add_argument("--emit-baseline", metavar="OUT", default=None,
                    help="also write the fresh runs' per-row minimum as a "
                         "baseline-shaped JSON (the CI re-baseline artifact)")
    ap.add_argument("--require-le", nargs=3, action="append", default=[],
                    metavar=("ROW", "REF", "RATIO"),
                    help="fail when fresh ROW > RATIO * fresh REF (repeatable; "
                         "relational gate within the same run, immune to "
                         "container drift)")
    args = ap.parse_args(argv)

    merged = _min_rows(args.fresh)
    if args.emit_baseline:
        _emit_baseline(args.emit_baseline, args.fresh, merged)

    relation_failures = check_relations(
        merged, [(row, ref, float(ratio)) for row, ref, ratio in args.require_le]
    )
    if relation_failures:
        print(
            f"\n{relation_failures} relational gate(s) failed", file=sys.stderr
        )
        return 1

    if args.baseline_from_artifact is not None:
        if args.baseline != "-":
            ap.error("pass '-' as the positional baseline with "
                     "--baseline-from-artifact (got both)")
        baseline_path = _resolve_artifact(args.baseline_from_artifact)
        print(f"baseline from artifact: {baseline_path}")
    elif args.baseline == "-":
        if args.emit_baseline:
            return 0  # emit-only invocation: nothing to gate against
        ap.error("'-' skips the gate only with --emit-baseline or "
                 "--baseline-from-artifact")
    else:
        baseline_path = args.baseline

    failures = compare(
        _load_rows(baseline_path), merged,
        max_ratio=args.max_ratio, min_us=args.min_us,
    )
    if failures:
        print(f"\n{failures} row(s) regressed beyond {args.max_ratio:g}x", file=sys.stderr)
        return 1
    print("\nno perf regressions beyond the gate")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
