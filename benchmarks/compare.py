"""Perf-regression gate: compare two bench JSONs row by row.

Used by the CI bench-smoke job to compare a freshly measured
``BENCH_clock_overhead.json`` against the committed baseline under
``benchmarks/baselines/``; exits non-zero when any matched row slowed down by
more than ``--max-ratio``.

Rows are matched by name.  Sub-resolution rows (both sides below ``--min-us``)
are ignored — micro-benchmark noise at those magnitudes is not a regression
signal.  Rows present in only one file are reported but do not fail the gate
(benches gain and rename rows across PRs); the gate's teeth are on the rows
both sides know about.

Several fresh JSONs may be passed; each row gates on its *minimum* across
them.  A real regression slows every run, while scheduler noise on a shared
runner inflates individual runs at random — min-of-N is the standard
microbenchmark noise filter (the bench itself already takes best-of-repeats
within a run; this extends it across process launches).

    python -m benchmarks.compare benchmarks/baselines/clock_overhead.json \
        BENCH_1.json BENCH_2.json BENCH_3.json --max-ratio 2.0
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def _load_rows(path: str) -> Dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {row["name"]: float(row["us_per_call"]) for row in payload["rows"]}


def compare(
    base: Dict[str, float],
    fresh: Dict[str, float],
    max_ratio: float = 2.0,
    min_us: float = 0.05,
) -> int:
    """Print the comparison table; return the number of failing rows."""
    failures = 0
    width = max([len(n) for n in {*base, *fresh}] + [len("row")]) + 2
    print(f"{'row'.ljust(width)} {'base_us':>12} {'new_us':>12} {'ratio':>8}  verdict")
    for name in sorted({*base, *fresh}):
        b, n = base.get(name), fresh.get(name)
        if b is None or n is None:
            which = "baseline" if b is None else "fresh run"
            print(f"{name.ljust(width)} {'-':>12} {'-':>12} {'-':>8}  SKIP (missing from {which})")
            continue
        if b < min_us and n < min_us:
            print(f"{name.ljust(width)} {b:12.3f} {n:12.3f} {'-':>8}  SKIP (below {min_us}us floor)")
            continue
        ratio = n / b if b > 0 else float("inf")
        verdict = "ok"
        if ratio > max_ratio:
            verdict = f"FAIL (> {max_ratio:g}x slowdown)"
            failures += 1
        print(f"{name.ljust(width)} {b:12.3f} {n:12.3f} {ratio:8.2f}  {verdict}")
    return failures


def _min_rows(paths) -> Dict[str, float]:
    """Per-row minimum across several fresh runs (noise filter)."""
    merged: Dict[str, float] = {}
    for path in paths:
        for name, value in _load_rows(path).items():
            if name not in merged or value < merged[name]:
                merged[name] = value
    return merged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", nargs="+",
                    help="freshly measured JSON(s); rows gate on their minimum")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when new/base exceeds this (default 2.0)")
    ap.add_argument("--min-us", type=float, default=0.05,
                    help="ignore rows where both sides are below this (noise floor)")
    args = ap.parse_args(argv)
    failures = compare(
        _load_rows(args.baseline), _min_rows(args.fresh),
        max_ratio=args.max_ratio, min_us=args.min_us,
    )
    if failures:
        print(f"\n{failures} row(s) regressed beyond {args.max_ratio:g}x", file=sys.stderr)
        return 1
    print("\nno perf regressions beyond the gate")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
