"""Checkpoint-path overheads: the fault-tolerance rows the CI perf gate pins.

Measures us per operation for the hardened checkpoint layer on a ~1 MB state
tree: the synchronous durable save (full write + hash-during-write + GC — the
eviction-barrier / preemption path), the async save's *blocking* phase (what
AdaptCheck actually bounds; the relational gate pins it well under the sync
cost), load-free validation (streamed sha256, the per-checkpoint resume-scan
cost), and a manager ``restore_latest`` (scan + validate + select + load).

Methodology matches bench_clock_overhead: each row is the best of ``repeats``
timed loops after a warmup call, everything on a tmpdir; ``--scale`` shrinks
iteration counts for smoke runs.
"""

from __future__ import annotations

import argparse
import json
import platform
import shutil
import sys
import tempfile
import time


def _time_op(fn, n: int, scale: float = 1.0, repeats: int = 3) -> float:
    n = max(int(n * scale), 3)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e6


def run(scale: float = 1.0) -> list[tuple[str, float, str]]:
    import numpy as np

    from repro.checkpoint import (
        CheckpointManager,
        save_checkpoint,
        validate_checkpoint,
    )

    # ~1 MB of state: big enough that hashing cost is real, small enough that
    # the smoke gate stays sub-second per row
    tree = {
        "params": {"w": np.arange(1 << 17, dtype=np.float32).reshape(512, 256)},
        "opt": {"m": np.zeros((1 << 17,), np.float32)},
        "step": np.int64(7),
    }
    rows: list[tuple[str, float, str]] = []
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        sync = CheckpointManager(f"{root}/sync", keep_n=2, synchronous=True)
        counter = iter(range(1, 1 << 20))

        def save_sync():
            sync.save(next(counter), tree)

        save_sync()
        rows.append(("ckpt/save_sync", _time_op(save_sync, 20, scale), "us_per_save"))
        sync.close()

        asy = CheckpointManager(f"{root}/async", keep_n=2, synchronous=False)

        def save_async_blocking():
            asy.save(next(counter), tree)

        save_async_blocking()
        rows.append((
            "ckpt/save_async_blocking",
            _time_op(save_async_blocking, 20, scale),
            "us_per_save",
        ))
        asy.close()

        path, _ = save_checkpoint(f"{root}/val", 1, tree)

        def validate():
            validate_checkpoint(path)

        validate()
        rows.append(("ckpt/validate", _time_op(validate, 40, scale), "us_per_call"))

        mgr = CheckpointManager(f"{root}/val", synchronous=True)

        def restore():
            mgr.restore_latest()

        restore()
        rows.append(("ckpt/restore_latest", _time_op(restore, 20, scale), "us_per_call"))
        mgr.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Hardened checkpoint-path overheads (CI perf-gate rows)."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="iteration-count multiplier (CI smoke: 0.5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if args.json:
        payload = {
            "bench": "checkpoint",
            "scale": args.scale,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [
                {"name": name, "us_per_call": value, "derived": derived}
                for name, value, derived in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
