"""Exporter-path overheads: the observability rows the CI perf gate pins.

Measures us per operation for the Prometheus export layer over a populated
timer database (a realistic mid-run shape: a scope tree, ADAPT decision rows,
parent-chain attribution at the LRU cap): ``collect`` (walk DB -> metric
families), ``render`` (families -> exposition text), ``parse`` (the strict
no-deps parser CI gates snapshots with), and ``write_textfile`` (atomic
tmp+rename, the node_exporter textfile-collector path).

Methodology matches bench_checkpoint: each row is the best of ``repeats``
timed loops after a warmup call; ``--scale`` shrinks iteration counts for
smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import sys
import tempfile
import time


def _time_op(fn, n: int, scale: float = 1.0, repeats: int = 3) -> float:
    n = max(int(n * scale), 3)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e6


def _populated_db():
    """A mid-run-shaped database: bins > thorns > scopes, ADAPT rows, and one
    hot timer driven past the parent-stats LRU cap."""
    from repro.core.timers import PARENT_STATS_CAP, TimerDB

    db = TimerDB()
    for b, thorn, n in (("EVOL", "trainer", 6), ("ANALYSIS", "adapt", 4),
                        ("CHECKPOINT", "adaptcheck", 3), ("OUTPUT", "report", 3)):
        for i in range(n):
            with db.scope(f"{b}/{thorn}::routine_{i}"):
                with db.scope(f"work/{b.lower()}_{i}"):
                    pass
    for action in ("grow", "shrink", "rebalance", "evict"):
        h = db.scope_handle(f"ADAPT/serving::{action}")
        h.timer.count += 5
    hot = db.scope_handle("hot/leaf")
    for i in range(PARENT_STATS_CAP + 32):
        with db.scope(f"caller_{i}"):
            with hot:
                pass
    return db


def run(scale: float = 1.0) -> list[tuple[str, float, str]]:
    from repro.monitor.export import MetricsExporter
    from repro.monitor.promparse import parse_exposition

    db = _populated_db()
    exporter = MetricsExporter(db)
    rows: list[tuple[str, float, str]] = []

    exporter.collect()
    rows.append(("export/collect", _time_op(exporter.collect, 200, scale),
                 "us_per_call"))

    text = exporter.render()
    rows.append(("export/render", _time_op(exporter.render, 200, scale),
                 "us_per_call"))

    parse_exposition(text)
    rows.append(("export/parse", _time_op(lambda: parse_exposition(text), 200, scale),
                 "us_per_call"))

    root = tempfile.mkdtemp(prefix="bench_export_")
    try:
        path = os.path.join(root, "metrics.prom")
        exporter.write_textfile(path)
        rows.append(("export/write_textfile",
                     _time_op(lambda: exporter.write_textfile(path), 100, scale),
                     "us_per_call"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Prometheus exporter-path overheads (CI perf-gate rows)."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="iteration-count multiplier (CI smoke: 0.5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if args.json:
        payload = {
            "bench": "export",
            "scale": args.scale,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [
                {"name": name, "us_per_call": value, "derived": derived}
                for name, value, derived in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
