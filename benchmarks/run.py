# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only clock_overhead

Benches (paper analogue in brackets):
    clock_overhead       [Tables 1-2 / §2 overhead]   timing-primitive costs
    timer_report         [Fig 2]                      report generation
    stage_distribution   [Fig 1 right]                bin wall-time shares
    adaptive_checkpoint  [Fig 3 / §4]                 fixed vs AdaptCheck (+ async)
    roofline             [deliverable g]              per-cell roofline fractions
"""

from __future__ import annotations

import argparse
import sys
import traceback


def _modules():
    from . import (
        bench_adaptive_checkpoint,
        bench_clock_overhead,
        bench_stage_distribution,
        bench_timer_report,
        roofline,
    )

    return {
        "clock_overhead": bench_clock_overhead.run,
        "timer_report": bench_timer_report.run,
        "stage_distribution": bench_stage_distribution.run,
        "adaptive_checkpoint": bench_adaptive_checkpoint.run,
        "roofline": roofline.run,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    benches = _modules()
    if args.only:
        benches = {k: v for k, v in benches.items() if k == args.only}
        if not benches:
            print(f"unknown bench {args.only}", file=sys.stderr)
            return 2
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        try:
            for row_name, value, derived in fn():
                print(f"{name}/{row_name},{value:.3f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},FAILED,", file=sys.stdout)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
