"""Paper Fig. 1 (right) analogue: wall-time distribution over schedule bins for
a real (small) training run through the full driver."""

from __future__ import annotations

from repro.core.params import reset_param_registry
from repro.core.timers import reset_timer_db
from repro.launch.train import TrainSettings, run_training


def run() -> list[tuple[str, float, str]]:
    reset_timer_db()
    reset_param_registry()
    summary = run_training(TrainSettings(
        arch="llama3.2-1b", smoke=True, steps=10, global_batch=2, seq_len=64,
        ckpt_dir="/tmp/bench_stage_ckpt", ckpt_mode="adaptive",
        ckpt_max_fraction=0.2, report_every=0, restore=False,
    ))
    rows: list[tuple[str, float, str]] = []
    total = sum(summary["bin_seconds"].values()) or 1.0
    for bin_name, seconds in sorted(summary["bin_seconds"].items()):
        rows.append((f"bin_seconds/{bin_name}", seconds * 1e6, "us_total"))
        rows.append((f"bin_share/{bin_name}", 100.0 * seconds / total, "percent"))
    return rows
