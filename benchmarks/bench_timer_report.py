"""Paper Fig. 2 analogue: timer-report generation cost vs database size."""

from __future__ import annotations

import time

from repro.core.report import format_report
from repro.core.timers import reset_timer_db


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    for n_timers in (10, 100, 500):
        db = reset_timer_db()
        for i in range(n_timers):
            h = db.create(f"EVOL/thorn{i % 7}::routine_{i}")
            db.start(h); db.stop(h)
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            text = format_report(db)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"format_report/{n_timers}_timers", us, "us_per_report"))
        t0 = time.perf_counter()
        for _ in range(reps):
            db.snapshot()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"snapshot/{n_timers}_timers", us, "us_per_snapshot"))
    assert "routine_0" in text
    return rows
