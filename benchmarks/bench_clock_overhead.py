"""Paper Tables 1-2 analogue: overhead of the timing primitives.

Measures us per operation for each built-in clock (start+stop+read), timer
creation, timer start/stop through the DB (including the hierarchy stack), and
a full scheduler-bin dispatch — the costs the paper's "high performance
interface" discussion cares about.

Methodology: each row is the best of ``repeats`` timed loops (micro-benchmark
noise floor); rows whose operation is cheaper than the loop dispatch overhead
are unrolled ``per`` times inside the timed callable and divided, so the
reported figure is the amortized per-operation cost.  Sections run against a
fresh timer DB each (row independence does not depend on section ordering),
and the global DB is re-reset at the end.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def _time_op(fn, n: int = 20000, scale: float = 1.0, per: int = 1, repeats: int = 5) -> float:
    """us per operation: best-of-``repeats`` loops, ``per`` ops per call."""
    n = max(int(n * scale), 50)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / (n * per) * 1e6


def run(scale: float = 1.0) -> list[tuple[str, float, str]]:
    """``scale`` shrinks/grows every iteration count (CI smoke uses ~0.05)."""
    from repro.core import clocks as C
    from repro.core.schedule import RunState, Scheduler
    from repro.core.timers import reset_timer_db

    rows: list[tuple[str, float, str]] = []

    # -- individual clock objects (classic slow-path API) ---------------------
    for name in ("walltime", "cputime", "perfcounter"):
        clk = C.make_clock(name)

        def cycle(clk=clk):
            clk.start(); clk.stop()

        rows.append((f"clock_start_stop/{name}", _time_op(cycle, scale=scale), "us_per_window"))
        rows.append((f"clock_read/{name}", _time_op(clk.read, scale=scale), "us_per_read"))

    counter = C.CounterClock("io", {"io_bytes": "bytes", "io_ops": "count"})
    rows.append(("clock_start_stop/counter2ch", _time_op(lambda: (counter.start(), counter.stop()), scale=scale), "us_per_window"))

    # -- counter increments ----------------------------------------------------
    # hot-path API: channel resolved once, increment is one C-level call
    cell = C.counter_cell("bench_cell")

    def bump_cell8():
        cell(1.0); cell(1.0); cell(1.0); cell(1.0)
        cell(1.0); cell(1.0); cell(1.0); cell(1.0)

    rows.append(("counter_increment", _time_op(bump_cell8, scale=scale, per=8), "us_per_call"))

    # compatibility API: name resolved on every call
    inc = C.increment_counter

    def bump_name8():
        inc("bench_name", 1.0); inc("bench_name", 1.0)
        inc("bench_name", 1.0); inc("bench_name", 1.0)
        inc("bench_name", 1.0); inc("bench_name", 1.0)
        inc("bench_name", 1.0); inc("bench_name", 1.0)

    rows.append(("counter_increment/by_name", _time_op(bump_name8, scale=scale, per=8), "us_per_call"))
    rows.append(("counter_read_channel", _time_op(lambda: C.counter_channel("bench_cell"), scale=scale), "us_per_read"))

    # -- timers through the DB (fused fast path) -------------------------------
    db = reset_timer_db()
    handle = db.create("bench")

    def timer_cycle():
        db.start(handle)
        db.stop(handle)

    rows.append(("timer_start_stop_all_clocks", _time_op(timer_cycle, 5000, scale), "us_per_window"))
    timer = db.get(handle)
    rows.append(("timer_read_flat", _time_op(timer.read_flat, 5000, scale), "us_per_read"))

    # -- hierarchical scopes (the repro.timing facade) --------------------------
    # pre-resolved handle: the facade hot path — must cost no more than the raw
    # handle start/stop above (gated in CI via compare.py --require-le)
    db = reset_timer_db()
    hot = db.scope_handle("bench/handle")

    def handle_cycle():
        with hot:
            pass

    rows.append(("scope_handle_enter_exit", _time_op(handle_cycle, 5000, scale), "us_per_window"))

    # dynamic scope: path joined under the enclosing scope per entry
    def scope_cycle():
        with db.scope("dyn"):
            pass

    rows.append(("scope_enter_exit", _time_op(scope_cycle, 5000, scale), "us_per_window"))

    # -- timer creation (fresh DB: row must not leak into other sections) ------
    db = reset_timer_db()
    i = [0]

    def creator():
        db.create(f"t{i[0]}")
        i[0] += 1

    rows.append(("timer_create", _time_op(creator, 2000, scale, repeats=1), "us_per_create"))

    # -- scheduler dispatch (fresh DB again) -----------------------------------
    sch = Scheduler(reset_timer_db())
    sch.schedule(lambda s: None, bin="EVOL", thorn="bench", name="noop")
    state = RunState(max_iterations=0)
    rows.append(
        ("scheduler_bin_dispatch", _time_op(lambda: sch.run_bin("EVOL", state), 2000, scale),
         "us_per_bin")
    )

    # leave the process-global DB clean for in-process callers
    reset_timer_db()
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Timing-primitive overheads (paper Tables 1-2 analogue)."
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="iteration-count multiplier (CI smoke: 0.05)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (BENCH_*.json perf trajectory)")
    args = ap.parse_args(argv)
    rows = run(scale=args.scale)
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if args.json:
        payload = {
            "bench": "clock_overhead",
            "scale": args.scale,
            "unix_time": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": [
                {"name": name, "us_per_call": value, "derived": derived}
                for name, value, derived in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
