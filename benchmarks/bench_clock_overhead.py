"""Paper Tables 1-2 analogue: overhead of the timing primitives.

Measures ns per operation for each built-in clock (start+stop+read), timer
creation, timer start/stop through the DB (including the hierarchy stack), and
a full scheduler-bin dispatch — the costs the paper's "high performance
interface" discussion cares about.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import clocks as C
from repro.core.schedule import RunState, Scheduler
from repro.core.timers import reset_timer_db


def _time_op(fn, n: int = 20000) -> float:
    """us per call."""
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for name in ("walltime", "cputime", "perfcounter"):
        clk = C.make_clock(name)

        def cycle(clk=clk):
            clk.start(); clk.stop()

        rows.append((f"clock_start_stop/{name}", _time_op(cycle), "us_per_window"))
        rows.append((f"clock_read/{name}", _time_op(clk.read), "us_per_read"))

    counter = C.CounterClock("io", {"io_bytes": "bytes", "io_ops": "count"})
    rows.append(("clock_start_stop/counter2ch", _time_op(lambda: (counter.start(), counter.stop())), "us_per_window"))
    rows.append(("counter_increment", _time_op(lambda: C.increment_counter("bench", 1.0)), "us_per_call"))

    db = reset_timer_db()
    handle = db.create("bench")

    def timer_cycle():
        db.start(handle)
        db.stop(handle)

    rows.append(("timer_start_stop_all_clocks", _time_op(timer_cycle, 5000), "us_per_window"))
    i = [0]

    def creator():
        db.create(f"t{i[0]}")
        i[0] += 1

    rows.append(("timer_create", _time_op(creator, 2000), "us_per_create"))

    sch = Scheduler(reset_timer_db())
    sch.schedule(lambda s: None, bin="EVOL", thorn="bench", name="noop")
    state = RunState(max_iterations=0)
    rows.append(
        ("scheduler_bin_dispatch", _time_op(lambda: sch.run_bin("EVOL", state), 2000),
         "us_per_bin")
    )
    return rows
