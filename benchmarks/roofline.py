"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod mesh, derives the three roofline terms:

    compute    = HLO_FLOPs_per_device        / peak_FLOP/s
    memory     = HLO_bytes_per_device        / HBM_bw
    collective = collective_bytes_per_device / link_bw

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (scan-trip-count
corrected by the dry-run, see launch/dryrun.py); collective bytes are summed
collective operand sizes parsed from the optimized per-device HLO.  All three
are *per-device* quantities, equivalent to the global-convention formula
``X_global / (chips × unit)`` since X_global = chips × X_per_device.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6·N·D (train) or 2·N·D (decode/prefill per-token
forward) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, plus the
dominant term and the roofline fraction
(= best-possible-time / dominant-term-time assuming perfect overlap).
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    variant: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    useful_ratio: float
    dominant: str
    roofline_fraction: float
    #: decode cells are inherently memory-bound; the meaningful efficiency is
    #: ideal bytes (params read once + cache touched once) / HLO bytes.
    memory_efficiency: float = 0.0
    note: str = ""

    @property
    def step_seconds_lower_bound(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def _model_flops(record: dict) -> float:
    """6·N_active·D for training, 2·N_active·D for forward-only steps."""
    mult = 6.0 if record["kind"] == "train" else 2.0
    return mult * record["params_active"] * record["tokens_per_step"] / record["n_chips"]


def analyze_record(record: dict) -> RooflineRow | None:
    if record.get("status") != "ok":
        return None
    cost = record["cost_analysis"]
    # microbatched steps: cost analysis sees one microbatch body (the scan
    # correction cannot see the accumulation loop) — scale to the full step
    accum = int(record.get("accum_steps", 1))
    flops = float(cost.get("flops", 0.0)) * accum
    nbytes = float(cost.get("bytes accessed", 0.0)) * accum
    coll = float(sum(record["collective_operand_bytes_per_device"].values())) * accum
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model = _model_flops(record)
    useful = model / flops if flops else 0.0
    # roofline fraction: useful model-compute time / achievable step time.
    ideal = model / PEAK_FLOPS
    bound = max(terms.values())
    frac = ideal / bound if bound > 0 else 0.0
    # decode: memory efficiency vs the ideal one-pass byte traffic
    state = record.get("state_bytes", {})
    ideal_bytes = state.get("params_bytes_per_device", 0) + state.get(
        "cache_bytes_per_device", 0
    )
    mem_eff = (ideal_bytes / nbytes) if nbytes and ideal_bytes else 0.0
    if record["kind"] == "decode":
        frac = mem_eff  # the meaningful roofline score for decode
    return RooflineRow(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        variant=record.get("variant", "baseline"), kind=record["kind"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops_per_dev=model, hlo_flops_per_dev=flops,
        useful_ratio=useful, dominant=dominant, roofline_fraction=frac,
        memory_efficiency=mem_eff,
    )


def load_rows(
    artifact_dir: str = ARTIFACT_DIR, mesh: str = "single", variant: str | None = "baseline"
) -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        record = json.load(open(path))
        if record.get("mesh") != mesh:
            continue
        if variant is not None and record.get("variant", "baseline") != variant:
            continue
        row = analyze_record(record)
        if row is not None:
            rows.append(row)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    header = (
        f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'collect_s':>10s} {'dominant':>10s} {'6ND/HLO':>8s} {'roofline':>9s}"
    )
    lines = [header, "-" * len(header)]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.compute_s:10.4f} {r.memory_s:10.4f} "
            f"{r.collective_s:10.4f} {r.dominant:>10s} {r.useful_ratio:8.3f} "
            f"{r.roofline_fraction:9.3f}"
        )
    return "\n".join(lines)


def run() -> list[tuple[str, float, str]]:
    """Benchmark-harness entry: roofline fraction per cell (single-pod)."""
    out: list[tuple[str, float, str]] = []
    rows = load_rows()
    for r in rows:
        out.append(
            (f"roofline/{r.arch}/{r.shape}", r.roofline_fraction * 100,
             f"pct_of_roofline_dominant={r.dominant}")
        )
    if rows:
        best = max(rows, key=lambda r: r.roofline_fraction)
        worst = min(rows, key=lambda r: r.roofline_fraction)
        out.append((f"roofline_best/{best.arch}/{best.shape}", best.roofline_fraction * 100, "pct"))
        out.append((f"roofline_worst/{worst.arch}/{worst.shape}", worst.roofline_fraction * 100, "pct"))
    return out


if __name__ == "__main__":
    rows = load_rows()
    print(format_table(rows))
