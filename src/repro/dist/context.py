"""Ambient sharding context: annotate tensors without threading (mesh, rules).

``use_sharding(mesh, rules)`` installs a thread-local (mesh, rules) pair for
the duration of a trace; ``constrain(x, *logical_axes)`` then resolves the
logical annotation against the ambient context and applies
``jax.lax.with_sharding_constraint``.  Outside any context — unit tests, CPU
smoke runs, eager debugging — ``constrain`` is a no-op, so model code carries
its sharding annotations unconditionally and stays runnable everywhere.
"""

from __future__ import annotations

import threading
from collections.abc import Iterator
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import ShardingRules, spec_for


__all__ = ["use_sharding", "current_sharding", "constrain"]

_STATE = threading.local()


@contextmanager
def use_sharding(mesh: Mesh, rules: ShardingRules) -> Iterator[None]:
    """Make (mesh, rules) the ambient sharding context; nestable."""
    previous = getattr(_STATE, "context", None)
    _STATE.context = (mesh, rules)
    try:
        yield
    finally:
        _STATE.context = previous


def current_sharding() -> tuple[Mesh, ShardingRules] | None:
    """The active (mesh, rules) pair, or ``None`` outside ``use_sharding``."""
    return getattr(_STATE, "context", None)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Constrain ``x`` to the sharding its logical axes resolve to.

    One ``logical_axes`` entry per dimension of ``x`` (``None`` = replicated
    dimension).  A no-op when no sharding context is active.
    """
    context = current_sharding()
    if context is None:
        return x
    mesh, rules = context
    spec = spec_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
