"""Logical-axis sharding: named tensor axes -> physical mesh axes.

Model code never mentions physical mesh axes.  Parameters and activations are
annotated with *logical* axis names (``"embed"``, ``"heads"``, ``"batch"`` ...)
and a :class:`ShardingRules` table maps each logical axis to zero or more mesh
axes.  :func:`spec_for` resolves one tensor's annotation into a
``PartitionSpec`` with three safety semantics (exercised by
``tests/test_sharding.py``):

* **absent-axis drop** — a rule naming a mesh axis the current mesh does not
  have is silently skipped, so the same rule table serves the 512-chip
  multi-pod mesh and a 1-CPU smoke run;
* **divisibility drop** — a mesh axis whose size does not divide the tensor
  dimension is skipped (XLA would otherwise pad or error);
* **once-per-tensor** — a mesh axis may shard at most one dimension of a given
  tensor; later uses are dropped.

Trailing ``None`` entries are trimmed so specs compare cleanly
(``P("data")``, not ``P("data", None)``).
"""

from __future__ import annotations

from collections.abc import Iterable, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "Axes",
    "ShardingRules",
    "DEFAULT_RULES",
    "FSDP_RULES",
    "spec_for",
    "tree_shardings",
]

AxisAssignment = str | Sequence[str] | None


class Axes(tuple):
    """Logical-axis annotation for one tensor (e.g. ``Axes(("embed", "heads"))``).

    A ``tuple`` subclass so it behaves like the axis tuple everywhere, but —
    unlike a plain tuple — jax's pytree machinery treats it as a *leaf*, which
    lets whole-tree operations (:func:`tree_shardings`) map an axes tree
    against a matching ``ShapeDtypeStruct`` tree.
    """

    __slots__ = ()

    def __new__(cls, axes: Iterable[str | None] = ()) -> Axes:
        return tuple.__new__(cls, tuple(axes))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Axes{tuple.__repr__(self)}"


def _normalize(assignment: AxisAssignment) -> tuple[str, ...]:
    if assignment is None:
        return ()
    if isinstance(assignment, str):
        return (assignment,)
    return tuple(assignment)


class ShardingRules:
    """Immutable table mapping logical axis names to mesh-axis assignments.

    Values may be ``None`` (replicate), one mesh axis name, or a sequence of
    mesh axes (the dimension is sharded over their product, e.g. ``"batch"``
    over ``("pod", "data")``).  Unknown logical axes resolve to ``()``.
    """

    __slots__ = ("_table",)

    def __init__(self, table: Mapping[str, AxisAssignment]) -> None:
        object.__setattr__(
            self, "_table", {k: _normalize(v) for k, v in table.items()}
        )

    def get(self, logical: str) -> tuple[str, ...]:
        """Mesh axes assigned to ``logical`` (``()`` if unmapped)."""
        return self._table.get(logical, ())

    def items(self):
        return self._table.items()

    def with_overrides(self, **overrides: AxisAssignment) -> ShardingRules:
        """A new table with some assignments replaced; ``self`` is untouched."""
        table: dict[str, AxisAssignment] = dict(self._table)
        table.update(overrides)
        return ShardingRules(table)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, ShardingRules) and self._table == other._table

    def __hash__(self) -> int:
        return hash(tuple(sorted(self._table.items())))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ShardingRules({self._table!r})"


#: Tensor-parallel default: contraction-heavy axes over "model", the global
#: batch over ("pod", "data"); everything else replicated.
DEFAULT_RULES = ShardingRules(
    {
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "ffn": ("model",),
        "expert": ("model",),
        "vocab": ("model",),
        "layers": (),
        "conv": (),
    }
)

#: tp+fsdp preset: like DEFAULT but parameters' "embed" dimension is sharded
#: over the data axis (ZeRO-3-style weight sharding; optimizer state inherits
#: it through ``opt_state_axes``).
FSDP_RULES = DEFAULT_RULES.with_overrides(embed=("data",))


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(mesh.shape)


def spec_for(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules,
) -> PartitionSpec:
    """Resolve one tensor's logical axes into a ``PartitionSpec``.

    ``axes`` and ``shape`` must have equal rank; ``None`` entries replicate
    that dimension.  See the module docstring for the drop semantics.
    """
    axes = tuple(axes)
    shape = tuple(shape)
    if len(axes) != len(shape):
        raise ValueError(
            f"rank mismatch: axes {axes} (rank {len(axes)}) vs shape {shape} "
            f"(rank {len(shape)})"
        )
    sizes = _mesh_sizes(mesh)
    used: set = set()
    entries: list = []
    for logical, dim in zip(axes, shape):
        assigned: list = []
        if logical is not None:
            factor = 1
            for mesh_axis in rules.get(logical):
                if mesh_axis not in sizes or mesh_axis in used:
                    continue
                grown = factor * sizes[mesh_axis]
                if dim % grown != 0:
                    continue
                factor = grown
                assigned.append(mesh_axis)
                used.add(mesh_axis)
        if not assigned:
            entries.append(None)
        elif len(assigned) == 1:
            entries.append(assigned[0])
        else:
            entries.append(tuple(assigned))
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def _is_axes_leaf(x: object) -> bool:
    return isinstance(x, Axes)


def tree_shardings(axes_tree, abstract_tree, mesh: Mesh, rules: ShardingRules):
    """Map an :class:`Axes` tree against a matching abstract-value tree into
    ``NamedSharding``s (the tree handed to ``jax.jit`` in/out shardings).

    ``abstract_tree`` leaves need only a ``.shape`` (``ShapeDtypeStruct`` or
    concrete arrays).  Empty subtrees (``()``/``{}``) pass through untouched.
    """

    def one(ax, abstract):
        return NamedSharding(mesh, spec_for(tuple(ax), tuple(abstract.shape), mesh, rules))

    return jax.tree.map(one, axes_tree, abstract_tree, is_leaf=_is_axes_leaf)
