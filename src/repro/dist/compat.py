"""Shims over jax API drift so the repo runs on the pinned container jax as
well as current releases.

Two surfaces moved between jax 0.4.x and 0.6+:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
  and renamed ``check_rep`` -> ``check_vma``;
* ``jax.make_mesh`` grew an ``axis_types`` keyword.

Callers use :func:`shard_map` / :func:`make_mesh` from here and stay agnostic.
"""

from __future__ import annotations

from collections.abc import Callable, Sequence
from typing import Any

import jax

__all__ = ["shard_map", "make_mesh", "cost_analysis"]


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across jax versions (older
    releases return a one-element list of per-program dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    check: bool = False,
) -> Callable:
    """Version-agnostic ``shard_map``.

    ``check`` maps to ``check_vma`` (new jax) / ``check_rep`` (old jax); it
    defaults off because the manual-collective kernels here (pipeline ticks,
    compressed all-reduce) intentionally produce unreplicated intermediates.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        try:
            return jax.shard_map(f, check_vma=check, **kwargs)
        except TypeError:
            pass
        try:
            # intermediate API generation: jax.shard_map with the old spelling
            return jax.shard_map(f, check_rep=check, **kwargs)
        except TypeError:
            return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[Any] | None = None,
    auto_axis_types: bool = False,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``."""
    kwargs: dict = {}
    if devices is not None:
        kwargs["devices"] = devices
    if auto_axis_types and hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                axis_shapes,
                axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
                **kwargs,
            )
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)
