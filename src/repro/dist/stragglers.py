"""Straggler detection from cross-host timer reductions (paper Sec. 1 & 5).

The paper's adaptive story needs timing data aggregated *across processes*: a
large run profiles itself and reacts.  :class:`StragglerDetector` is that
reduction point for step walltimes — each host's per-step seconds stream in
(directly via :meth:`observe`, sampled out of the timer database via
:meth:`observe_timer`, or all-gathered from every host through an injectable
:class:`LocalTransport`), and :meth:`check` compares per-host windowed means
against the fleet median.  Hosts slower than ``threshold`` x median are flagged
in a :class:`StragglerReport`, handed to the ``on_straggler`` callback (the
hook a launcher uses to re-shard, evict, or alert), and published back into the
timer database as ``DIST/host{h}::step`` timers so distributed health appears
in the Fig.-2-style report next to every other profile row.

Acting on stragglers (rebalance / evict) lives one layer up in
:mod:`repro.adapt.stragglers`; this module supplies the two mechanisms that
make acting possible: the transport (so every host feeds the reduction, not
just host 0) and :meth:`StragglerDetector.evict` (so a removed host drops out
of the fleet median while its history stays visible in the report).
"""

from __future__ import annotations

import threading
from collections import deque
from collections.abc import Callable
from dataclasses import dataclass

from ..core.timers import TimerDB, timer_db

__all__ = ["LocalTransport", "StragglerDetector", "StragglerReport"]


class LocalTransport:
    """In-process step-time all-gather — the injectable reduction feed.

    Replaces the host-0-only feed: every host (real process or simulated
    participant) calls :meth:`publish` with its step walltime, and the reducing
    side calls :meth:`gather` to drain everyone's pending samples.  Real
    multi-process deployments implement the same two-call surface over an
    actual collective (a jax process-group all-gather or a sidecar KV store);
    the in-process version makes the full measure→decide→migrate loop testable
    on one CPU (see :class:`repro.adapt.SimulatedFleet`).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._pending: dict[int, list[float]] = {}
        self._dropped: set[int] = set()

    def publish(self, host: int, seconds: float) -> None:
        """Record one step walltime from ``host`` (dropped hosts are ignored)."""
        with self._lock:
            if host in self._dropped:
                return
            self._pending.setdefault(host, []).append(float(seconds))

    def gather(self) -> dict[int, list[float]]:
        """Drain and return all pending samples, keyed by host."""
        with self._lock:
            out, self._pending = self._pending, {}
        return out

    def drop_host(self, host: int) -> None:
        """Stop accepting samples from ``host`` (eviction path)."""
        with self._lock:
            self._dropped.add(host)
            self._pending.pop(host, None)

    @property
    def dropped(self) -> frozenset:
        with self._lock:
            return frozenset(self._dropped)


@dataclass(frozen=True)
class StragglerReport:
    """One fleet-health snapshot produced by :meth:`StragglerDetector.check`."""

    step: int
    #: windowed mean step-seconds per host (only active hosts with observations)
    host_means: dict[int, float]
    #: median of ``host_means`` values — the fleet's "normal" step time
    median: float
    #: hosts whose mean exceeds ``threshold * median``
    stragglers: list[int]
    threshold: float

    def slowdown(self, host: int) -> float:
        """How many x slower than the fleet median ``host`` is."""
        if self.median <= 0.0 or host not in self.host_means:
            return 0.0
        return self.host_means[host] / self.median


class StragglerDetector:
    """Windowed cross-host step-time reduction with median-ratio flagging.

    Parameters
    ----------
    n_hosts:
        Number of hosts expected to report (hosts are dense ints ``0..n-1``).
    window:
        Number of most-recent observations per host entering the mean.
    threshold:
        A host is a straggler when ``mean > threshold * median(all means)``.
    on_straggler:
        Called with the :class:`StragglerReport` whenever a check flags at
        least one host.
    publish:
        When true (default), each :meth:`check` mirrors per-host totals into
        the timer database as ``DIST/host{h}::step`` rows.
    transport:
        Optional :class:`LocalTransport`-shaped feed.  When set, every
        :meth:`check` (or an explicit :meth:`drain_transport`) first gathers
        and records all hosts' published step times — the multi-process
        reduction path.
    """

    def __init__(
        self,
        n_hosts: int,
        window: int = 32,
        threshold: float = 2.0,
        on_straggler: Callable[[StragglerReport], None] | None = None,
        publish: bool = True,
        db: TimerDB | None = None,
        transport: LocalTransport | None = None,
    ) -> None:
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if threshold <= 1.0:
            raise ValueError(f"threshold must exceed 1.0, got {threshold}")
        self.n_hosts = n_hosts
        self.window = window
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.publish = publish
        self.transport = transport
        self._db = db
        self._windows: list[deque[float]] = [deque(maxlen=window) for _ in range(n_hosts)]
        self._totals: list[float] = [0.0] * n_hosts
        self._counts: list[int] = [0] * n_hosts
        #: (cumulative seconds, cumulative count) last sampled per db timer
        self._timer_marks: dict[tuple[int, str], tuple[float, int]] = {}
        self.reports: list[StragglerReport] = []
        #: hosts removed from the fleet by :meth:`evict` — kept in
        #: :meth:`host_stats` history but excluded from means and flagging
        self.evicted: set[int] = set()

    # -- feeding observations --------------------------------------------------
    def _record(self, host: int, mean_seconds: float, total: float, windows: int) -> None:
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} out of range [0, {self.n_hosts})")
        if host in self.evicted:  # late samples from a removed host
            return
        self._windows[host].append(float(mean_seconds))
        self._totals[host] += float(total)
        self._counts[host] += windows

    def observe(self, host: int, seconds: float) -> None:
        """Record one step walltime for ``host``."""
        self._record(host, seconds, seconds, 1)

    def drain_transport(self) -> int:
        """Gather and record every host's published step times; returns the
        number of samples recorded.  No-op without a transport."""
        if self.transport is None:
            return 0
        n = 0
        for host, samples in self.transport.gather().items():
            if not 0 <= host < self.n_hosts or host in self.evicted:
                continue
            for seconds in samples:
                self._record(host, seconds, seconds, 1)
                n += 1
        return n

    def observe_timer(self, host: int, timer_name: str, db: TimerDB | None = None) -> None:
        """Sample ``host``'s step time out of the timer database.

        Reads the named timer's cumulative walltime and window count, and
        observes the *mean seconds per window since the last sample* — the
        cross-process reduction path: each host ships its timer-DB readings and
        the detector diffs them, so instrumented code needs no extra hooks.
        Samplers sparser than one call per step stay exact: the full delta
        (all elapsed windows and seconds) is credited to :meth:`host_stats`,
        while the windowed mean enters the straggler comparison once.
        """
        db = db or self._db or timer_db()
        if not db.exists(timer_name):
            return
        timer = db.get(timer_name)
        # seconds() is the single-channel fast read off the flat accumulator
        # and stays correct when a collision namespaces the walltime channel
        seconds, count = timer.seconds(), timer.count
        last_seconds, last_count = self._timer_marks.get((host, timer_name), (0.0, 0))
        d_count = count - last_count
        if d_count > 0:
            delta = seconds - last_seconds
            self._record(host, delta / d_count, delta, d_count)
            self._timer_marks[(host, timer_name)] = (seconds, count)

    # -- membership -------------------------------------------------------------
    def add_host(self, host: int) -> None:
        """Grow the fleet to include ``host`` (elastic membership: a mid-run
        join).  Hosts stay dense ints; growing to ``host`` allocates empty
        windows for any ids in between.  Re-adding a previously evicted id is
        rejected — a rejoining physical node takes a fresh id, so its stale
        history can never pollute the new incarnation's judgment."""
        host = int(host)
        if host < 0:
            raise ValueError(f"host must be >= 0, got {host}")
        if host in self.evicted:
            raise ValueError(
                f"host {host} was evicted; rejoin under a fresh host id"
            )
        while self.n_hosts <= host:
            self._windows.append(deque(maxlen=self.window))
            self._totals.append(0.0)
            self._counts.append(0)
            self.n_hosts += 1

    def evict(self, host: int) -> None:
        """Remove ``host`` from the fleet (the straggler-response eviction
        path): its window is cleared, future samples are dropped, and it no
        longer enters the median or gets flagged.  Its cumulative
        :meth:`host_stats` history stays visible in the report."""
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} out of range [0, {self.n_hosts})")
        active = [h for h in range(self.n_hosts) if h not in self.evicted]
        if host not in self.evicted and len(active) <= 1:
            raise ValueError("cannot evict the last active host")
        self.evicted.add(host)
        self._windows[host].clear()
        if self.transport is not None:
            self.transport.drop_host(host)

    def active_hosts(self) -> list[int]:
        return [h for h in range(self.n_hosts) if h not in self.evicted]

    def reset_window(self, host: int) -> None:
        """Clear ``host``'s windowed samples (cumulative history stays).

        Call after the host's work assignment changes (e.g. a microbatch
        rebalance): samples measured under the old assignment no longer
        describe the host's current speed, and leaving them in the window
        makes a just-fixed host look slow for ``window`` more checks —
        compounding derates and, at the weight floor, spurious eviction.
        """
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} out of range [0, {self.n_hosts})")
        self._windows[host].clear()

    # -- queries ----------------------------------------------------------------
    def host_stats(self) -> dict[int, tuple[int, float]]:
        """{host: (n_observations, total_seconds)} over the whole run (hosts
        with at least one observation only; evicted hosts keep their history)."""
        return {
            host: (self._counts[host], self._totals[host])
            for host in range(self.n_hosts)
            if self._counts[host] > 0
        }

    def host_means(self) -> dict[int, float]:
        """Windowed mean step-seconds per active host (hosts with data only)."""
        return {
            host: sum(w) / len(w)
            for host, w in enumerate(self._windows)
            if len(w) > 0 and host not in self.evicted
        }

    def check(self, step: int) -> StragglerReport:
        """Reduce current windows into a report; flag, callback, and publish.

        Drains the transport first (when one is injected), so a bare
        ``check()`` on the reducing host sees every host's latest samples.
        """
        self.drain_transport()
        means = self.host_means()
        median = _median(list(means.values())) if means else 0.0
        stragglers = sorted(
            host
            for host, mean in means.items()
            if median > 0.0 and mean > self.threshold * median
        )
        report = StragglerReport(
            step=step,
            host_means=means,
            median=median,
            stragglers=stragglers,
            threshold=self.threshold,
        )
        self.reports.append(report)
        if self.publish:
            self.publish_to_db(self._db or timer_db())
        if stragglers and self.on_straggler is not None:
            self.on_straggler(report)
        return report

    def publish_to_db(self, db: TimerDB, prefix: str = "DIST") -> None:
        """Mirror per-host totals into ``{prefix}/host{h}::step`` timer rows.

        Uses the timer ``set_channel`` API (Cactus ``CCTK_TimerSet`` analogue),
        so the fleet-health rows render in ``core.report.format_report``
        exactly like locally measured timers.  Rows are resolved through the
        database's cached scope handles (the ``repro.timing`` path→timer
        resolution), so repeated publishes skip the locked create/lookup.
        """
        from ..core.timers import TimerError

        for host, (count, total) in self.host_stats().items():
            timer = db.scope_handle(f"{prefix}/host{host}::step").timer
            try:
                timer.set_channel("walltime", total)
            except TimerError:  # no walltime clock registered: count-only row
                pass
            timer.count = count


def _median(values: list[float]) -> float:
    ordered = sorted(values)
    n = len(ordered)
    if n == 0:
        return 0.0
    mid = n // 2
    if n % 2 == 1:
        return ordered[mid]
    return 0.5 * (ordered[mid - 1] + ordered[mid])
