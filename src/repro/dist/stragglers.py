"""Straggler detection from cross-host timer reductions (paper Sec. 1 & 5).

The paper's adaptive story needs timing data aggregated *across processes*: a
large run profiles itself and reacts.  :class:`StragglerDetector` is that
reduction point for step walltimes — each host's per-step seconds stream in
(directly via :meth:`observe`, or sampled out of the timer database via
:meth:`observe_timer`), and :meth:`check` compares per-host windowed means
against the fleet median.  Hosts slower than ``threshold`` x median are flagged
in a :class:`StragglerReport`, handed to the ``on_straggler`` callback (the
hook a launcher uses to re-shard, evict, or alert), and published back into the
timer database as ``DIST/host{h}::step`` timers so distributed health appears
in the Fig.-2-style report next to every other profile row.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

from ..core.timers import TimerDB, timer_db

__all__ = ["StragglerDetector", "StragglerReport"]


@dataclass(frozen=True)
class StragglerReport:
    """One fleet-health snapshot produced by :meth:`StragglerDetector.check`."""

    step: int
    #: windowed mean step-seconds per host (only hosts with observations)
    host_means: Dict[int, float]
    #: median of ``host_means`` values — the fleet's "normal" step time
    median: float
    #: hosts whose mean exceeds ``threshold * median``
    stragglers: List[int]
    threshold: float

    def slowdown(self, host: int) -> float:
        """How many x slower than the fleet median ``host`` is."""
        if self.median <= 0.0 or host not in self.host_means:
            return 0.0
        return self.host_means[host] / self.median


class StragglerDetector:
    """Windowed cross-host step-time reduction with median-ratio flagging.

    Parameters
    ----------
    n_hosts:
        Number of hosts expected to report (hosts are dense ints ``0..n-1``).
    window:
        Number of most-recent observations per host entering the mean.
    threshold:
        A host is a straggler when ``mean > threshold * median(all means)``.
    on_straggler:
        Called with the :class:`StragglerReport` whenever a check flags at
        least one host.
    publish:
        When true (default), each :meth:`check` mirrors per-host totals into
        the timer database as ``DIST/host{h}::step`` rows.
    """

    def __init__(
        self,
        n_hosts: int,
        window: int = 32,
        threshold: float = 2.0,
        on_straggler: Optional[Callable[[StragglerReport], None]] = None,
        publish: bool = True,
        db: Optional[TimerDB] = None,
    ) -> None:
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if threshold <= 1.0:
            raise ValueError(f"threshold must exceed 1.0, got {threshold}")
        self.n_hosts = n_hosts
        self.window = window
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.publish = publish
        self._db = db
        self._windows: List[Deque[float]] = [deque(maxlen=window) for _ in range(n_hosts)]
        self._totals: List[float] = [0.0] * n_hosts
        self._counts: List[int] = [0] * n_hosts
        #: (cumulative seconds, cumulative count) last sampled per db timer
        self._timer_marks: Dict[Tuple[int, str], Tuple[float, int]] = {}
        self.reports: List[StragglerReport] = []

    # -- feeding observations --------------------------------------------------
    def _record(self, host: int, mean_seconds: float, total: float, windows: int) -> None:
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} out of range [0, {self.n_hosts})")
        self._windows[host].append(float(mean_seconds))
        self._totals[host] += float(total)
        self._counts[host] += windows

    def observe(self, host: int, seconds: float) -> None:
        """Record one step walltime for ``host``."""
        self._record(host, seconds, seconds, 1)

    def observe_timer(self, host: int, timer_name: str, db: Optional[TimerDB] = None) -> None:
        """Sample ``host``'s step time out of the timer database.

        Reads the named timer's cumulative walltime and window count, and
        observes the *mean seconds per window since the last sample* — the
        cross-process reduction path: each host ships its timer-DB readings and
        the detector diffs them, so instrumented code needs no extra hooks.
        Samplers sparser than one call per step stay exact: the full delta
        (all elapsed windows and seconds) is credited to :meth:`host_stats`,
        while the windowed mean enters the straggler comparison once.
        """
        db = db or self._db or timer_db()
        if not db.exists(timer_name):
            return
        timer = db.get(timer_name)
        # seconds() is the single-channel fast read off the flat accumulator
        # and stays correct when a collision namespaces the walltime channel
        seconds, count = timer.seconds(), timer.count
        last_seconds, last_count = self._timer_marks.get((host, timer_name), (0.0, 0))
        d_count = count - last_count
        if d_count > 0:
            delta = seconds - last_seconds
            self._record(host, delta / d_count, delta, d_count)
            self._timer_marks[(host, timer_name)] = (seconds, count)

    # -- queries ----------------------------------------------------------------
    def host_stats(self) -> Dict[int, Tuple[int, float]]:
        """{host: (n_observations, total_seconds)} over the whole run (hosts
        with at least one observation only)."""
        return {
            host: (self._counts[host], self._totals[host])
            for host in range(self.n_hosts)
            if self._counts[host] > 0
        }

    def host_means(self) -> Dict[int, float]:
        """Windowed mean step-seconds per host (hosts with data only)."""
        return {
            host: sum(w) / len(w)
            for host, w in enumerate(self._windows)
            if len(w) > 0
        }

    def check(self, step: int) -> StragglerReport:
        """Reduce current windows into a report; flag, callback, and publish."""
        means = self.host_means()
        median = _median(list(means.values())) if means else 0.0
        stragglers = sorted(
            host
            for host, mean in means.items()
            if median > 0.0 and mean > self.threshold * median
        )
        report = StragglerReport(
            step=step,
            host_means=means,
            median=median,
            stragglers=stragglers,
            threshold=self.threshold,
        )
        self.reports.append(report)
        if self.publish:
            self.publish_to_db(self._db or timer_db())
        if stragglers and self.on_straggler is not None:
            self.on_straggler(report)
        return report

    def publish_to_db(self, db: TimerDB, prefix: str = "DIST") -> None:
        """Mirror per-host totals into ``{prefix}/host{h}::step`` timer rows.

        Uses the timer ``set_channel`` API (Cactus ``CCTK_TimerSet`` analogue),
        so the fleet-health rows render in ``core.report.format_report``
        exactly like locally measured timers.
        """
        from ..core.timers import TimerError

        for host, (count, total) in self.host_stats().items():
            timer = db.get(db.create(f"{prefix}/host{host}::step"))
            try:
                timer.set_channel("walltime", total)
            except TimerError:  # no walltime clock registered: count-only row
                pass
            timer.count = count


def _median(values: List[float]) -> float:
    ordered = sorted(values)
    n = len(ordered)
    if n == 0:
        return 0.0
    mid = n // 2
    if n % 2 == 1:
        return ordered[mid]
    return 0.5 * (ordered[mid - 1] + ordered[mid])
