"""GPipe-style pipeline parallelism over one mesh axis.

``gpipe_forward`` places consecutive layer stages on consecutive devices along
a mesh axis and streams microbatches through them: at tick ``t`` stage 0
ingests microbatch ``t`` while every other stage works on the activation its
predecessor shipped via ``ppermute`` at tick ``t-1``.  After
``n_micro + n_stages - 1`` ticks the last stage has emitted every microbatch.

This is the forward-only schedule (serving / dry-run measurement path); the
bubble fraction is ``(n_stages - 1) / (n_micro + n_stages - 1)``, so more
microbatches amortize the fill/drain cost exactly as in the GPipe paper.

:class:`MicrobatchPlan` is the fleet-level assignment above ``gpipe_forward``:
a weighted split of the global microbatch count across data-parallel hosts.
Each host feeds its share through its own pipeline; the straggler-response
controller (:mod:`repro.adapt.stragglers`) shrinks a slow host's weight so its
share — and therefore its per-step walltime — drops, and removes the host
entirely on eviction.
"""

from __future__ import annotations

from collections.abc import Callable, Iterable
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

__all__ = ["MicrobatchPlan", "gpipe_forward"]


@dataclass
class MicrobatchPlan:
    """Weighted assignment of ``n_micro`` microbatches to data-parallel hosts.

    ``weights`` maps each active host to a positive weight; :meth:`shares`
    apportions the global microbatch count proportionally (largest-remainder
    rounding) with every active host guaranteed at least one microbatch, so a
    rebalanced host still participates until it is explicitly evicted.
    """

    n_micro: int
    weights: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.n_micro < max(len(self.weights), 1):
            raise ValueError(
                f"n_micro={self.n_micro} cannot cover {len(self.weights)} hosts "
                f"with at least one microbatch each"
            )
        for host, w in self.weights.items():
            if w <= 0.0:
                raise ValueError(f"host {host} weight must be positive, got {w}")

    @classmethod
    def equal(cls, hosts: Iterable[int], n_micro: int) -> MicrobatchPlan:
        """Uniform plan over ``hosts`` (the pre-adaptation default)."""
        return cls(n_micro=n_micro, weights={int(h): 1.0 for h in hosts})

    @property
    def hosts(self) -> list[int]:
        return sorted(self.weights)

    def set_weight(self, host: int, weight: float) -> None:
        if host not in self.weights:
            raise ValueError(f"host {host} is not in the plan {self.hosts}")
        if weight <= 0.0:
            raise ValueError(f"weight must be positive, got {weight}")
        self.weights[host] = float(weight)

    def evict(self, host: int) -> None:
        """Remove ``host``; its share is re-apportioned among survivors."""
        if host not in self.weights:
            raise ValueError(f"host {host} is not in the plan {self.hosts}")
        if len(self.weights) <= 1:
            raise ValueError("cannot evict the last host in the plan")
        del self.weights[host]

    def shares(self) -> dict[int, int]:
        """{host: microbatch count}; counts sum to ``n_micro``, each >= 1."""
        hosts = self.hosts
        if not hosts:
            raise ValueError("plan has no hosts")
        total_w = sum(self.weights.values())
        extra = self.n_micro - len(hosts)  # one reserved per host
        quotas = {h: extra * self.weights[h] / total_w for h in hosts}
        counts = {h: int(quotas[h]) for h in hosts}
        leftover = extra - sum(counts.values())
        # largest remainder, host id as the deterministic tie-break
        by_remainder = sorted(hosts, key=lambda h: (counts[h] - quotas[h], h))
        for h in by_remainder[:leftover]:
            counts[h] += 1
        return {h: counts[h] + 1 for h in hosts}

    def share(self, host: int) -> int:
        return self.shares()[host]


def gpipe_forward(
    layer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,
    x: jax.Array,
    *,
    mesh: Mesh,
    axis: str,
    n_micro: int,
) -> jax.Array:
    """Run ``x`` through stacked stages pipelined over ``mesh`` axis ``axis``.

    ``stage_params`` has a leading stage dimension (``n_stages, ...``); stage
    ``i`` computes ``layer_fn(stage_params[i], activation)`` and must preserve
    the activation's shape and dtype (homogeneous pipeline).  ``n_stages`` must
    be a multiple of the mesh axis size (each device runs a contiguous group of
    stages) and ``x.shape[0]`` a multiple of ``n_micro``.
    """
    n_stages = int(stage_params.shape[0])
    axis_size = int(mesh.shape[axis])
    if n_stages % axis_size != 0:
        raise ValueError(
            f"n_stages={n_stages} must be a multiple of mesh axis {axis!r} "
            f"size {axis_size}"
        )
    batch = int(x.shape[0])
    if batch % n_micro != 0:
        raise ValueError(f"batch {batch} not divisible by n_micro={n_micro}")
    micro_batch = batch // n_micro
    micro_shape = (micro_batch,) + x.shape[1:]

    out_abstract = jax.eval_shape(
        layer_fn,
        jax.ShapeDtypeStruct(stage_params.shape[1:], stage_params.dtype),
        jax.ShapeDtypeStruct(micro_shape, x.dtype),
    )
    if out_abstract.shape != micro_shape or out_abstract.dtype != x.dtype:
        raise ValueError(
            f"layer_fn must preserve activation shape/dtype for pipelining; "
            f"got {out_abstract.shape}/{out_abstract.dtype} from "
            f"{micro_shape}/{x.dtype}"
        )

    shift = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    n_ticks = n_micro + axis_size - 1

    def pipelined(stages_local: jax.Array, x_full: jax.Array) -> jax.Array:
        stage_index = jax.lax.axis_index(axis)
        micro = x_full.reshape((n_micro,) + micro_shape)

        def run_local_stages(activation: jax.Array) -> jax.Array:
            def one_stage(act, w):
                return layer_fn(w, act), None

            result, _ = jax.lax.scan(one_stage, activation, stages_local)
            return result

        def tick(t, carry):
            inflight, outputs = carry
            feed = jax.lax.dynamic_index_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), keepdims=False
            )
            activation = jnp.where(stage_index == 0, feed, inflight)
            produced = run_local_stages(activation)
            # the last device commits microbatch t-(axis_size-1); earlier
            # devices (and warm-up ticks) leave the zero buffer untouched
            out_index = jnp.clip(t - (axis_size - 1), 0, n_micro - 1)
            commit = jnp.logical_and(t >= axis_size - 1, stage_index == axis_size - 1)
            current = jax.lax.dynamic_index_in_dim(outputs, out_index, keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(commit, produced, current), out_index, 0
            )
            inflight = jax.lax.ppermute(produced, axis, shift)
            return inflight, outputs

        inflight0 = jnp.zeros(micro_shape, x.dtype)
        outputs0 = jnp.zeros((n_micro,) + micro_shape, x.dtype)
        _, outputs = jax.lax.fori_loop(0, n_ticks, tick, (inflight0, outputs0))
        # only the last device holds non-zero outputs; psum replicates them
        outputs = jax.lax.psum(outputs, axis)
        return outputs.reshape((batch,) + x.shape[1:])

    # P(axis) on the stage dimension leaves each device a contiguous
    # (n_stages // axis_size, ...) block of consecutive stages
    fn = shard_map(
        pipelined, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(), check=False
    )
    return fn(stage_params, x)
