"""Pipeline parallelism over one mesh axis: forward-only GPipe and 1F1B.

``gpipe_forward`` places consecutive layer stages on consecutive devices along
a mesh axis and streams microbatches through them: at tick ``t`` stage 0
ingests microbatch ``t`` while every other stage works on the activation its
predecessor shipped via ``ppermute`` at tick ``t-1``.  After
``n_micro + n_stages - 1`` ticks the last stage has emitted every microbatch.
It is the forward-only schedule (serving / dry-run measurement path); the
bubble fraction is ``(n_stages - 1) / (n_micro + n_stages - 1)``, so more
microbatches amortize the fill/drain cost exactly as in the GPipe paper.

:class:`PipelineStep` / :func:`pipeline_step` add the training schedule: a
**1F1B** (one-forward-one-backward) tick loop that returns the loss *and*
per-stage parameter gradients.  The schedule runs two counter-rotating
``ppermute`` rings — activations forward, activation-gradients backward —
driven by one global tick clock ``t``:

* stage ``d`` runs the *forward* of microbatch ``m`` at tick ``m + d``;
* stage ``d`` runs the *backward* of microbatch ``m`` at tick
  ``m + 2S - 1 - d`` (``S`` = pipeline depth), i.e. the loss gradient enters
  the last stage one tick after that microbatch's forward leaves it.

Ticks ``[0, S)`` are pure **warmup** (forward fill), ticks ``[S, M + S - 1)``
are **steady state** — every stage performs exactly one forward and one
backward micro-step per tick — and ticks ``[M + S - 1, M + 2S - 1)`` are
**cooldown** (backward drain).  :func:`phase_ticks` exposes these ranges and
:class:`PipelineStep` can execute them as three separately dispatched
segments so a launcher can time each phase (``phase_cb``).

Memory is the 1F1B win: each stage keeps only its *in-flight* stage-input
activations in a ring stash of ``min(2S, M)`` microbatch slots — sized by the
pipeline depth, **not** by ``n_micro`` (GPipe's forward-then-backward order
stashes all ``M``).  The backward recomputes the local stage group under
``jax.vjp`` from the stashed input (standard rematerialization), so the stash
holds one activation per in-flight microbatch and nothing else.

Fleet-level assignment objects sit above the schedules:

* :class:`MicrobatchPlan` — weighted largest-remainder split of the global
  microbatch count across data-parallel hosts (every active host >= 1).
* :class:`StagePlan` — the same apportionment over *pipeline stage depth*:
  ``n_layers`` contiguous layers split across stages by capacity weight
  (every stage >= 1 layer).  :meth:`StagePlan.pack` turns a flat per-layer
  parameter stack into the padded ``(n_stages * max_depth, ...)`` slot array
  (+ active mask) that :func:`pipeline_step` consumes, so the
  straggler-response controller can *move stage boundaries* at run time
  (``restage``) and the very next step executes the new split.
"""

from __future__ import annotations

import functools
from collections.abc import Callable, Iterable, Mapping
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

__all__ = [
    "MicrobatchPlan",
    "PipelineStep",
    "StagePlan",
    "gpipe_forward",
    "phase_ticks",
    "pipeline_step",
]


# ---------------------------------------------------------------------------
# Weighted largest-remainder apportionment (shared by both plan types)
# ---------------------------------------------------------------------------

def _largest_remainder(weights: Mapping[int, float], total: int) -> dict[int, int]:
    """Apportion ``total`` indivisible units over ``weights`` proportionally.

    Every key receives at least one unit (one unit per key is reserved before
    the proportional split; the remainder is rounded largest-remainder with
    the key id as the deterministic tie-break).  The result satisfies the
    quota rule on the non-reserved part: each share is ``1 + floor(q)`` or
    ``1 + ceil(q)`` for quota ``q = extra * w / sum(w)`` — the invariant the
    property tests in ``tests/test_properties.py`` pin.
    """
    keys = sorted(weights)
    if not keys:
        raise ValueError("cannot apportion over an empty weight map")
    if total < len(keys):
        raise ValueError(
            f"total={total} cannot cover {len(keys)} entries with >= 1 each"
        )
    total_w = sum(weights.values())
    extra = total - len(keys)  # one reserved per key
    quotas = {k: extra * weights[k] / total_w for k in keys}
    counts = {k: int(quotas[k]) for k in keys}
    leftover = extra - sum(counts.values())
    by_remainder = sorted(keys, key=lambda k: (counts[k] - quotas[k], k))
    for k in by_remainder[:leftover]:
        counts[k] += 1
    return {k: counts[k] + 1 for k in keys}


@dataclass
class MicrobatchPlan:
    """Weighted assignment of ``n_micro`` microbatches to data-parallel hosts.

    ``weights`` maps each active host to a positive weight; :meth:`shares`
    apportions the global microbatch count proportionally (largest-remainder
    rounding) with every active host guaranteed at least one microbatch, so a
    rebalanced host still participates until it is explicitly evicted.
    """

    n_micro: int
    weights: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.n_micro < max(len(self.weights), 1):
            raise ValueError(
                f"n_micro={self.n_micro} cannot cover {len(self.weights)} hosts "
                f"with at least one microbatch each"
            )
        for host, w in self.weights.items():
            if w <= 0.0:
                raise ValueError(f"host {host} weight must be positive, got {w}")

    @classmethod
    def equal(cls, hosts: Iterable[int], n_micro: int) -> MicrobatchPlan:
        """Uniform plan over ``hosts`` (the pre-adaptation default)."""
        return cls(n_micro=n_micro, weights={int(h): 1.0 for h in hosts})

    @property
    def hosts(self) -> list[int]:
        return sorted(self.weights)

    def set_weight(self, host: int, weight: float) -> None:
        if host not in self.weights:
            raise ValueError(f"host {host} is not in the plan {self.hosts}")
        if weight <= 0.0:
            raise ValueError(f"weight must be positive, got {weight}")
        self.weights[host] = float(weight)

    def evict(self, host: int) -> None:
        """Remove ``host``; its share is re-apportioned among survivors."""
        if host not in self.weights:
            raise ValueError(f"host {host} is not in the plan {self.hosts}")
        if len(self.weights) <= 1:
            raise ValueError("cannot evict the last host in the plan")
        del self.weights[host]

    def shares(self) -> dict[int, int]:
        """{host: microbatch count}; counts sum to ``n_micro``, each >= 1."""
        if not self.weights:
            raise ValueError("plan has no hosts")
        return _largest_remainder(self.weights, self.n_micro)

    def retarget(self, hosts: Iterable[int]) -> MicrobatchPlan:
        """Re-apportion onto a different host set (restore into an N→M
        topology).  Hosts present in both keep their learned capacity
        weights; new hosts enter at the carried mean weight, so a restored
        fleet neither punishes newcomers nor forgets which survivors were
        derated.  The largest-remainder :meth:`shares` then re-splits the
        same ``n_micro`` across the new set."""
        hosts = [int(h) for h in hosts]
        if not hosts:
            raise ValueError("cannot retarget onto an empty host set")
        mean = sum(self.weights.values()) / len(self.weights)
        return MicrobatchPlan(
            n_micro=self.n_micro,
            weights={h: float(self.weights.get(h, mean)) for h in hosts},
        )

    def share(self, host: int) -> int:
        return self.shares()[host]


@dataclass
class StagePlan:
    """Weighted split of ``n_layers`` contiguous layers across pipeline stages.

    The stage-depth analogue of :class:`MicrobatchPlan`: ``weights`` maps each
    pipeline stage (rank along the pipeline mesh axis) to a positive capacity
    weight, and :meth:`depths` apportions the layer count proportionally
    (largest-remainder, every stage >= 1 layer).  The straggler-response
    controller derates a slow stage-owner's weight (``restage`` action) so the
    stage boundary moves and the slow device runs fewer layers per microbatch.

    :meth:`pack` / :meth:`unpack` translate between the flat per-layer
    parameter stack and the padded per-stage slot layout
    (``n_stages * max_depth`` rows + active mask) that :func:`pipeline_step`
    executes, so a launcher applies a restage by simply re-packing before the
    next step.
    """

    n_layers: int
    weights: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.weights:
            raise ValueError("StagePlan needs at least one stage")
        if self.n_layers < len(self.weights):
            raise ValueError(
                f"n_layers={self.n_layers} cannot cover {len(self.weights)} "
                f"stages with at least one layer each"
            )
        for stage, w in self.weights.items():
            if w <= 0.0:
                raise ValueError(f"stage {stage} weight must be positive, got {w}")

    @classmethod
    def equal(cls, stages: Iterable[int], n_layers: int) -> StagePlan:
        """Uniform plan over ``stages`` (the pre-adaptation default)."""
        return cls(n_layers=n_layers, weights={int(s): 1.0 for s in stages})

    @property
    def stages(self) -> list[int]:
        return sorted(self.weights)

    @property
    def n_stages(self) -> int:
        return len(self.weights)

    def set_weight(self, stage: int, weight: float) -> None:
        if stage not in self.weights:
            raise ValueError(f"stage {stage} is not in the plan {self.stages}")
        if weight <= 0.0:
            raise ValueError(f"weight must be positive, got {weight}")
        self.weights[stage] = float(weight)

    def depths(self) -> dict[int, int]:
        """{stage: layer count}; counts sum to ``n_layers``, each >= 1."""
        return _largest_remainder(self.weights, self.n_layers)

    def retarget(self, stages: Iterable[int]) -> StagePlan:
        """Re-apportion the same ``n_layers`` onto a different stage set
        (restore into an N→M pipeline).  Stages present in both keep their
        learned capacity weights; new stages enter at the carried mean, and
        :meth:`depths` re-splits the layer stack — the flat per-layer
        parameter checkpoint then :meth:`pack`\\ s into the new topology
        without any tensor surgery."""
        stages = [int(s) for s in stages]
        if not stages:
            raise ValueError("cannot retarget onto an empty stage set")
        if self.n_layers < len(stages):
            raise ValueError(
                f"n_layers={self.n_layers} cannot cover {len(stages)} stages"
            )
        mean = sum(self.weights.values()) / len(self.weights)
        return StagePlan(
            n_layers=self.n_layers,
            weights={s: float(self.weights.get(s, mean)) for s in stages},
        )

    def boundaries(self) -> dict[int, tuple[int, int]]:
        """{stage: [start, stop) layer range} in stage order."""
        depths = self.depths()
        out: dict[int, tuple[int, int]] = {}
        start = 0
        for stage in self.stages:
            out[stage] = (start, start + depths[stage])
            start += depths[stage]
        return out

    def max_depth(self) -> int:
        return max(self.depths().values())

    def pack(self, layer_params) -> tuple[Any, jax.Array]:
        """Pad a flat per-layer parameter pytree into pipeline slots.

        ``layer_params`` is any pytree whose leaves share a leading
        ``n_layers`` dimension (a bare ``(n_layers, ...)`` array or a
        transformer block stack).  Returns ``(packed, mask)``: every packed
        leaf has leading ``n_stages * max_depth`` where stage ``s`` owns the
        contiguous slot block ``[s * max_depth, (s+1) * max_depth)`` holding
        its layers front-aligned; ``mask`` is the matching boolean
        slot-activity vector (inactive slots are identity in the pipeline and
        receive zero gradient).  Padding makes unequal stage depths executable
        under the SPMD schedule, whose per-device blocks must be equal-sized.
        """
        lmax, rows = self._slot_rows()
        n_slots = self.n_stages * lmax
        index = jnp.asarray(rows)

        def _pack_leaf(leaf):
            if int(leaf.shape[0]) != self.n_layers:
                raise ValueError(
                    f"layer_params leaf has {leaf.shape[0]} layers, plan "
                    f"covers {self.n_layers}"
                )
            out = jnp.zeros((n_slots,) + tuple(leaf.shape[1:]), leaf.dtype)
            return out.at[index].set(leaf)

        packed = jax.tree.map(_pack_leaf, layer_params)
        mask = jnp.zeros((n_slots,), bool).at[index].set(True)
        return packed, mask

    def unpack(self, packed):
        """Gather the active slots of a packed pytree (e.g. per-slot
        gradients) back into the flat ``(n_layers, ...)`` layer order."""
        lmax, rows = self._slot_rows()
        index = jnp.asarray(rows)

        def _unpack_leaf(leaf):
            if int(leaf.shape[0]) != self.n_stages * lmax:
                raise ValueError(
                    f"packed leaf has {leaf.shape[0]} slots, plan packs to "
                    f"{self.n_stages * lmax}"
                )
            return leaf[index]

        return jax.tree.map(_unpack_leaf, packed)

    def _slot_rows(self) -> tuple[int, list[int]]:
        """``(max_depth, slot index of each flat layer in layer order)`` —
        one apportionment pass serves both pack() and unpack(), which sit on
        the per-step hot path (the live-restage re-pack)."""
        depths = self.depths()
        lmax = max(depths.values())
        rows: list[int] = []
        for i, stage in enumerate(self.stages):
            rows.extend(i * lmax + j for j in range(depths[stage]))
        return lmax, rows


# ---------------------------------------------------------------------------
# Forward-only (GPipe) schedule
# ---------------------------------------------------------------------------

def gpipe_forward(
    layer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,
    x: jax.Array,
    *,
    mesh: Mesh,
    axis: str,
    n_micro: int,
) -> jax.Array:
    """Run ``x`` through stacked stages pipelined over ``mesh`` axis ``axis``.

    ``stage_params`` has a leading stage dimension (``n_stages, ...``); stage
    ``i`` computes ``layer_fn(stage_params[i], activation)`` and must preserve
    the activation's shape and dtype (homogeneous pipeline).  ``n_stages`` must
    be a multiple of the mesh axis size (each device runs a contiguous group of
    stages) and ``x.shape[0]`` a multiple of ``n_micro``.
    """
    n_stages = int(stage_params.shape[0])
    axis_size = int(mesh.shape[axis])
    if n_stages % axis_size != 0:
        raise ValueError(
            f"n_stages={n_stages} must be a multiple of mesh axis {axis!r} "
            f"size {axis_size}"
        )
    batch = int(x.shape[0])
    if batch % n_micro != 0:
        raise ValueError(f"batch {batch} not divisible by n_micro={n_micro}")
    micro_batch = batch // n_micro
    micro_shape = (micro_batch,) + x.shape[1:]

    out_abstract = jax.eval_shape(
        layer_fn,
        jax.ShapeDtypeStruct(stage_params.shape[1:], stage_params.dtype),
        jax.ShapeDtypeStruct(micro_shape, x.dtype),
    )
    if out_abstract.shape != micro_shape or out_abstract.dtype != x.dtype:
        raise ValueError(
            f"layer_fn must preserve activation shape/dtype for pipelining; "
            f"got {out_abstract.shape}/{out_abstract.dtype} from "
            f"{micro_shape}/{x.dtype}"
        )

    shift = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    n_ticks = n_micro + axis_size - 1

    def pipelined(stages_local: jax.Array, x_full: jax.Array) -> jax.Array:
        stage_index = jax.lax.axis_index(axis)
        micro = x_full.reshape((n_micro,) + micro_shape)

        def run_local_stages(activation: jax.Array) -> jax.Array:
            def one_stage(act, w):
                return layer_fn(w, act), None

            result, _ = jax.lax.scan(one_stage, activation, stages_local)
            return result

        def tick(t, carry):
            inflight, outputs = carry
            feed = jax.lax.dynamic_index_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), keepdims=False
            )
            activation = jnp.where(stage_index == 0, feed, inflight)
            produced = run_local_stages(activation)
            # the last device commits microbatch t-(axis_size-1); earlier
            # devices (and warm-up ticks) leave the zero buffer untouched
            out_index = jnp.clip(t - (axis_size - 1), 0, n_micro - 1)
            commit = jnp.logical_and(t >= axis_size - 1, stage_index == axis_size - 1)
            current = jax.lax.dynamic_index_in_dim(outputs, out_index, keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(commit, produced, current), out_index, 0
            )
            inflight = jax.lax.ppermute(produced, axis, shift)
            return inflight, outputs

        inflight0 = jnp.zeros(micro_shape, x.dtype)
        outputs0 = jnp.zeros((n_micro,) + micro_shape, x.dtype)
        _, outputs = jax.lax.fori_loop(0, n_ticks, tick, (inflight0, outputs0))
        # only the last device holds non-zero outputs; psum replicates them
        outputs = jax.lax.psum(outputs, axis)
        return outputs.reshape((batch,) + x.shape[1:])

    # P(axis) on the stage dimension leaves each device a contiguous
    # (n_stages // axis_size, ...) block of consecutive stages
    fn = shard_map(
        pipelined, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(), check=False
    )
    return fn(stage_params, x)


# ---------------------------------------------------------------------------
# 1F1B training schedule
# ---------------------------------------------------------------------------

def phase_ticks(n_micro: int, axis_size: int) -> dict[str, tuple[int, int]]:
    """The 1F1B global tick ranges: ``{phase: [start, stop)}``.

    *warmup* is the forward fill (no backward active anywhere), *steady* the
    one-forward-one-backward regime, *cooldown* the backward drain (no forward
    active anywhere).  The full schedule is ``n_micro + 2 * axis_size - 1``
    ticks; ranges may be empty (e.g. steady when ``n_micro < axis_size``).
    """
    s, m = int(axis_size), int(n_micro)
    return {
        "warmup": (0, s),
        "steady": (s, max(m + s - 1, s)),
        "cooldown": (max(m + s - 1, s), m + 2 * s - 1),
    }


def _leaf_key(tree) -> tuple:
    """Hashable (structure, shapes, dtypes) signature of a pytree."""
    leaves, treedef = jax.tree.flatten(tree)
    return (
        str(treedef),
        tuple((tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves),
    )


class PipelineStep:
    """Reusable 1F1B pipeline train step over one mesh axis.

    Builds (and caches, per input shape/dtype signature) the jitted tick
    runner once; every ``__call__`` then executes the schedule and returns
    ``(loss, grads)`` where ``loss`` is the mean of the per-microbatch loss
    and ``grads`` matches ``stage_params``'s structure (per-slot parameter
    gradients of that mean loss).

    ``stage_params`` may be a bare ``(n_slots, ...)`` array or any pytree
    whose leaves share the leading slot dimension (e.g. a transformer block
    stack) — :meth:`StagePlan.pack` produces either.

    Parameters
    ----------
    layer_fn:
        ``layer_fn(slot_params, activation) -> activation`` — must preserve
        activation shape/dtype (homogeneous pipeline).  ``slot_params`` is
        one slot's slice of the ``stage_params`` pytree.
    loss_fn:
        ``loss_fn(final_activation, target_microbatch) -> scalar``; it is
        evaluated (and differentiated) on the last stage only.  ``None`` is
        allowed iff ``last_fn`` is given (the head then owns the loss).
    mesh / axis:
        The pipeline mesh axis.  The slot count must be a multiple of the
        axis size; each device runs a contiguous slot block.
    n_micro:
        Microbatch count ``M``; ``x.shape[0]`` must be divisible by it.
    first_fn / last_fn:
        Stage-pinning hooks for heterogeneous ends of the pipeline (both or
        neither).  ``first_fn(first_params, raw_microbatch) -> activation``
        runs pinned to stage 0 (the embedding: ``x`` then carries raw inputs,
        e.g. int32 tokens, and the activation shape/dtype is inferred from
        ``first_fn``); ``last_fn(last_params, activation, target_microbatch)
        -> scalar`` runs pinned to the final stage (norm + head + loss) and
        replaces ``loss_fn``.  ``__call__`` then takes ``first_params`` /
        ``last_params`` and returns ``(loss, (stage_grads, first_grads,
        last_grads))``.
    phase_cb:
        Optional ``phase_cb(name) -> context manager`` for
        ``warmup``/``steady``/``cooldown``.  When set, the schedule executes
        as three separately dispatched (and synchronized) segments with the
        callback's context open around each — the launcher hook that times
        phases as ``repro.timing`` scopes.  When unset the whole schedule is
        one fused dispatch.
    stage_spec:
        Optional ``PartitionSpec`` pytree (or prefix) for the packed stage
        parameters, composing per-stage tensor-parallel/FSDP sharding with
        the pipeline axis: every leaf spec's leading entry must be the
        pipeline ``axis`` (the slot dimension); trailing entries shard the
        parameter dimensions over the mesh's inner axes.  Defaults to
        ``P(axis)`` (stage-sharded, otherwise replicated).  Applied to both
        the stage params input and the gradient accumulator carry.
    """

    def __init__(
        self,
        layer_fn: Callable[[Any, jax.Array], jax.Array],
        loss_fn: Callable[[jax.Array, jax.Array], jax.Array] | None,
        *,
        mesh: Mesh,
        axis: str,
        n_micro: int,
        first_fn: Callable[[Any, jax.Array], jax.Array] | None = None,
        last_fn: Callable[[Any, jax.Array, jax.Array], jax.Array] | None = None,
        phase_cb: Callable[[str], object] | None = None,
        stage_spec: Any | None = None,
    ) -> None:
        if (first_fn is None) != (last_fn is None):
            raise ValueError(
                "first_fn and last_fn pin the pipeline's heterogeneous ends "
                "together: pass both or neither"
            )
        if loss_fn is None and last_fn is None:
            raise ValueError("loss_fn may only be None when last_fn is given")
        self.layer_fn = layer_fn
        self.loss_fn = loss_fn
        self.first_fn = first_fn
        self.last_fn = last_fn
        self.mesh = mesh
        self.axis = axis
        self.n_micro = int(n_micro)
        self.phase_cb = phase_cb
        self.stage_spec = stage_spec if stage_spec is not None else P(axis)
        self.axis_size = int(mesh.shape[axis])
        if self.n_micro < 1:
            raise ValueError(f"n_micro must be >= 1, got {n_micro}")
        self._runners: dict[tuple, Callable] = {}

    # -- public entry ---------------------------------------------------------
    def __call__(
        self,
        stage_params: Any,
        x: jax.Array,
        targets: jax.Array,
        stage_mask: jax.Array | None = None,
        *,
        first_params: Any = None,
        last_params: Any = None,
    ):
        s, m = self.axis_size, self.n_micro
        hooks = self.last_fn is not None
        if hooks and (first_params is None or last_params is None):
            raise ValueError(
                "first_params/last_params are required when first_fn/last_fn "
                "are set"
            )
        if not hooks and (first_params is not None or last_params is not None):
            raise ValueError(
                "first_params/last_params given but the step has no "
                "first_fn/last_fn"
            )
        leaves = jax.tree.leaves(stage_params)
        if not leaves:
            raise ValueError("stage_params has no array leaves")
        n_slots = int(leaves[0].shape[0])
        for leaf in leaves:
            if int(leaf.shape[0]) != n_slots:
                raise ValueError(
                    f"stage_params leaves disagree on the slot dimension: "
                    f"{leaf.shape[0]} != {n_slots}"
                )
        if n_slots % s != 0:
            raise ValueError(
                f"n_slots={n_slots} must be a multiple of mesh axis "
                f"{self.axis!r} size {s}"
            )
        batch = int(x.shape[0])
        if batch % m != 0:
            raise ValueError(f"batch {batch} not divisible by n_micro={m}")
        if int(targets.shape[0]) != batch:
            raise ValueError(
                f"targets leading dim {targets.shape[0]} != batch {batch}"
            )
        if stage_mask is None:
            stage_mask = jnp.ones((n_slots,), bool)
        elif stage_mask.shape != (n_slots,):
            raise ValueError(
                f"stage_mask shape {stage_mask.shape} != ({n_slots},)"
            )
        in_micro_shape = (batch // m,) + x.shape[1:]
        tmicro_shape = (batch // m,) + targets.shape[1:]
        if hooks:
            act_abs = jax.eval_shape(
                self.first_fn, first_params,
                jax.ShapeDtypeStruct(in_micro_shape, x.dtype),
            )
            if not hasattr(act_abs, "shape"):
                raise ValueError("first_fn must return a single array")
            micro_shape, act_dtype = tuple(act_abs.shape), act_abs.dtype
        else:
            micro_shape, act_dtype = in_micro_shape, x.dtype

        key = (
            _leaf_key(stage_params),
            _leaf_key(first_params), _leaf_key(last_params),
            x.shape, str(x.dtype), targets.shape, str(targets.dtype),
        )
        runner = self._runners.get(key)
        if runner is None:
            runner = self._build(
                n_slots, in_micro_shape, micro_shape, tmicro_shape,
                x.dtype, act_dtype, targets.dtype,
                stage_params, first_params, last_params,
            )
            self._runners[key] = runner

        micro = x.reshape((m,) + in_micro_shape)
        tmicro = targets.reshape((m,) + tmicro_shape)
        r = min(2 * s, m)
        zeros_like_stacked = (
            lambda tree, lead: jax.tree.map(
                lambda leaf: jnp.zeros(lead + tuple(leaf.shape), leaf.dtype), tree
            )
        )
        carry = (
            jnp.zeros((s,) + micro_shape, act_dtype),          # forward ring
            jnp.zeros((s,) + micro_shape, act_dtype),          # backward ring
            jnp.zeros((s, r) + micro_shape, act_dtype),        # input stash
            jnp.zeros((s, r) + micro_shape, act_dtype),        # loss-grad seeds
            jnp.zeros((s,), jnp.float32),                      # per-device loss
            jax.tree.map(                                      # per-slot grads
                lambda leaf: jnp.zeros(leaf.shape, leaf.dtype), stage_params
            ),
            zeros_like_stacked(first_params, (s,)) if hooks else (),
            zeros_like_stacked(last_params, (s,)) if hooks else (),
        )
        if self.phase_cb is None:
            carry = runner(stage_params, stage_mask, first_params, last_params,
                           micro, tmicro, carry, 0, m + 2 * s - 1)
        else:
            for name, (t0, t1) in phase_ticks(m, s).items():
                if t1 <= t0:
                    continue
                with self.phase_cb(name):
                    carry = runner(stage_params, stage_mask, first_params,
                                   last_params, micro, tmicro, carry, t0, t1)
                    # synchronize inside the scope so the caliper window
                    # covers the phase's device work, not just its dispatch
                    jax.block_until_ready(carry[4])
        loss = jnp.sum(carry[4])  # only the last stage accumulated loss
        if not hooks:
            return loss, carry[5]
        # the pinned-stage accumulators are stacked over the pipeline axis;
        # only the pinned stage contributed non-zeros, so the sum extracts it
        first_grads = jax.tree.map(lambda a: jnp.sum(a, axis=0), carry[6])
        last_grads = jax.tree.map(lambda a: jnp.sum(a, axis=0), carry[7])
        return loss, (carry[5], first_grads, last_grads)

    # -- schedule construction -------------------------------------------------
    def _build(self, n_slots, in_micro_shape, micro_shape, tmicro_shape,
               x_dtype, act_dtype, t_dtype,
               stage_params, first_params, last_params):
        s, m = self.axis_size, self.n_micro
        r = min(2 * s, m)
        axis, layer_fn, loss_fn = self.axis, self.layer_fn, self.loss_fn
        first_fn, last_fn = self.first_fn, self.last_fn
        hooks = last_fn is not None
        fwd_ring = [(i, (i + 1) % s) for i in range(s)]
        bwd_ring = [(i, (i - 1) % s) for i in range(s)]

        slot_abs = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(tuple(leaf.shape[1:]), leaf.dtype),
            stage_params,
        )
        act_sds = jax.ShapeDtypeStruct(micro_shape, act_dtype)
        tgt_sds = jax.ShapeDtypeStruct(tmicro_shape, t_dtype)
        out_abstract = jax.eval_shape(layer_fn, slot_abs, act_sds)
        if out_abstract.shape != micro_shape or out_abstract.dtype != act_dtype:
            raise ValueError(
                f"layer_fn must preserve activation shape/dtype for "
                f"pipelining; got {out_abstract.shape}/{out_abstract.dtype} "
                f"from {micro_shape}/{act_dtype}"
            )
        if hooks:
            loss_abstract = jax.eval_shape(last_fn, last_params, act_sds, tgt_sds)
        else:
            loss_abstract = jax.eval_shape(loss_fn, act_sds, tgt_sds)
        if loss_abstract.shape != ():
            raise ValueError(
                f"loss_fn must return a scalar, got shape {loss_abstract.shape}"
            )

        def local(stages_local, mask_local, act):
            # inactive slots (StagePlan padding) are identity and therefore
            # contribute exactly zero gradient
            def one(a, wm):
                w, active = wm
                return jnp.where(active, layer_fn(w, a), a), None

            res, _ = jax.lax.scan(one, act, (stages_local, mask_local))
            return res

        def _masked_add(acc_tree, d_tree, flag):
            return jax.tree.map(
                lambda acc, d: acc + jnp.where(flag, d, jnp.zeros_like(d)),
                acc_tree, d_tree,
            )

        def shard_body(stage_params, stage_mask, first_params, last_params,
                       micro, tmicro, carry, t0, t1):
            d = jax.lax.axis_index(axis)
            is_first = d == 0
            is_last = d == s - 1

            def tick(t, c):
                recv_f, recv_b, stash, seed, loss_sum, gacc, fgacc, lgacc = c
                # ---- forward: microbatch t - d ----
                mf = t - d
                active_f = jnp.logical_and(mf >= 0, mf < m)
                mf_c = jnp.clip(mf, 0, m - 1)
                raw = jax.lax.dynamic_index_in_dim(micro, mf_c, keepdims=False)
                feed = first_fn(first_params, raw) if hooks else raw
                act_in = jnp.where(is_first, feed, recv_f)
                slot_f = jnp.mod(mf_c, r)
                cur = jax.lax.dynamic_index_in_dim(stash, slot_f, keepdims=False)
                stash = jax.lax.dynamic_update_index_in_dim(
                    stash, jnp.where(active_f, act_in, cur), slot_f, 0
                )
                y = local(stage_params, stage_mask, act_in)
                # last stage: fold the loss in and stash its gradient seed for
                # the backward tick one step later
                tgt = jax.lax.dynamic_index_in_dim(tmicro, mf_c, keepdims=False)
                take_loss = jnp.logical_and(active_f, is_last)
                if hooks:
                    lm, (gm, glast) = jax.value_and_grad(
                        lambda yy, lp: last_fn(lp, yy, tgt), argnums=(0, 1)
                    )(y, last_params)
                    lgacc = _masked_add(
                        lgacc,
                        jax.tree.map(lambda g: g / m, glast),
                        take_loss,
                    )
                else:
                    lm, gm = jax.value_and_grad(lambda yy: loss_fn(yy, tgt))(y)
                loss_sum = loss_sum + jnp.where(take_loss, lm, 0.0) / m
                curs = jax.lax.dynamic_index_in_dim(seed, slot_f, keepdims=False)
                seed = jax.lax.dynamic_update_index_in_dim(
                    seed, jnp.where(take_loss, gm / m, curs), slot_f, 0
                )
                send_f = jax.lax.ppermute(y, axis, fwd_ring)
                # ---- backward: microbatch t - (2S - 1 - d) ----
                mb = t - (2 * s - 1 - d)
                active_b = jnp.logical_and(mb >= 0, mb < m)
                mb_c = jnp.clip(mb, 0, m - 1)
                slot_b = jnp.mod(mb_c, r)
                act_b = jax.lax.dynamic_index_in_dim(stash, slot_b, keepdims=False)
                g_seed = jax.lax.dynamic_index_in_dim(seed, slot_b, keepdims=False)
                g_in = jnp.where(is_last, g_seed, recv_b)
                # rematerialize the local stage group from the stashed input;
                # only the stage inputs are kept in-flight (the 1F1B stash)
                _, vjp = jax.vjp(
                    lambda w, a: local(w, stage_mask, a), stage_params, act_b
                )
                dw, dact = vjp(g_in)
                gacc = _masked_add(gacc, dw, active_b)
                if hooks:
                    # stage 0's activation gradient flows into the pinned
                    # first_fn (the embedding); recompute its vjp from the
                    # raw microbatch input
                    raw_b = jax.lax.dynamic_index_in_dim(
                        micro, mb_c, keepdims=False
                    )
                    _, vjp_first = jax.vjp(
                        lambda fp: first_fn(fp, raw_b), first_params
                    )
                    (dfp,) = vjp_first(dact)
                    fgacc = _masked_add(
                        fgacc, dfp, jnp.logical_and(active_b, is_first)
                    )
                send_b = jax.lax.ppermute(
                    jnp.where(active_b, dact, jnp.zeros_like(dact)),
                    axis, bwd_ring,
                )
                return (send_f, send_b, stash, seed, loss_sum, gacc,
                        fgacc, lgacc)

            (recv_f, recv_b, stash, seed, loss_sum, gacc, fgacc, lgacc) = carry
            head = lambda tree: jax.tree.map(lambda a: a[0], tree)
            c = (recv_f[0], recv_b[0], stash[0], seed[0], loss_sum[0], gacc,
                 head(fgacc), head(lgacc))
            c = jax.lax.fori_loop(t0, t1, tick, c)
            recv_f, recv_b, stash, seed, loss_sum, gacc, fgacc, lgacc = c
            unhead = lambda tree: jax.tree.map(lambda a: a[None], tree)
            return (recv_f[None], recv_b[None], stash[None], seed[None],
                    loss_sum[None], gacc, unhead(fgacc), unhead(lgacc))

        carry_specs = (P(axis), P(axis), P(axis), P(axis), P(axis),
                       self.stage_spec, P(axis), P(axis))
        smapped = shard_map(
            shard_body,
            mesh=self.mesh,
            in_specs=(self.stage_spec, P(axis), P(), P(), P(), P(),
                      carry_specs, None, None),
            out_specs=carry_specs,
            check=False,
        )

        @functools.partial(jax.jit, static_argnums=(7, 8))
        def run(stage_params, stage_mask, first_params, last_params,
                micro, tmicro, carry, t0, t1):
            return smapped(stage_params, stage_mask, first_params, last_params,
                           micro, tmicro, carry, t0, t1)

        return run


def pipeline_step(
    layer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,
    x: jax.Array,
    targets: jax.Array,
    *,
    loss_fn: Callable[[jax.Array, jax.Array], jax.Array],
    mesh: Mesh,
    axis: str,
    n_micro: int,
    stage_mask: jax.Array | None = None,
    phase_cb: Callable[[str], object] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One-shot 1F1B step: ``(loss, per-slot grads)`` for ``x``/``targets``.

    Convenience wrapper over :class:`PipelineStep` (which hot loops should
    construct once and reuse — the jitted tick runner is cached on the
    instance, so a fresh ``pipeline_step`` call re-traces).
    """
    step = PipelineStep(
        layer_fn, loss_fn, mesh=mesh, axis=axis, n_micro=n_micro,
        phase_cb=phase_cb,
    )
    return step(stage_params, x, targets, stage_mask)
