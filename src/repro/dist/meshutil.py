"""Local mesh construction for launchers, tests, and CI.

``local_mesh`` builds a device mesh from a settings-style ``mesh_shape`` over
whatever devices this process has — one CPU in unit tests, eight forced host
devices in the mini dry-run, real accelerators in production — with clear
errors when the requested shape cannot be satisfied.  Production pod topologies
live in :mod:`repro.launch.mesh`; this module is the everything-else path.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from .compat import make_mesh

__all__ = ["local_mesh", "default_axis_names"]

_AXIS_NAMES_BY_RANK = {
    1: ("data",),
    2: ("data", "model"),
    3: ("pod", "data", "model"),
}


def default_axis_names(rank: int) -> Tuple[str, ...]:
    """Conventional axis names for a mesh of the given rank."""
    if rank not in _AXIS_NAMES_BY_RANK:
        raise ValueError(
            f"no default axis names for a rank-{rank} mesh; pass axis_names "
            f"explicitly (defaults exist for ranks {sorted(_AXIS_NAMES_BY_RANK)})"
        )
    return _AXIS_NAMES_BY_RANK[rank]


def local_mesh(
    mesh_shape: Sequence[int] = (1, 1),
    axis_names: Optional[Sequence[str]] = None,
) -> Mesh:
    """Build a mesh of ``mesh_shape`` from this process's devices.

    CPU-friendly: a ``(1, 1)`` shape on a single-CPU host yields a 1-device
    ``("data", "model")`` mesh, so the same launcher code path runs in CI and
    at scale.  Uses the first ``prod(mesh_shape)`` devices, so a smaller mesh
    than the host's device count is allowed.
    """
    shape = tuple(int(s) for s in mesh_shape)
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh_shape must be non-empty positive ints, got {mesh_shape!r}")
    names = tuple(axis_names) if axis_names is not None else default_axis_names(len(shape))
    if len(names) != len(shape):
        raise ValueError(f"axis_names {names} does not match mesh_shape {shape}")
    n_needed = math.prod(shape)
    devices = jax.devices()
    if n_needed > len(devices):
        raise ValueError(
            f"mesh_shape {shape} needs {n_needed} devices but only "
            f"{len(devices)} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_needed} for CPU dry-runs"
        )
    return make_mesh(shape, names, devices=devices[:n_needed])
