"""Local mesh construction for launchers, tests, and CI.

``local_mesh`` builds a device mesh from a settings-style ``mesh_shape`` over
whatever devices this process has — one CPU in unit tests, eight forced host
devices in the mini dry-run, real accelerators in production — with clear
errors when the requested shape cannot be satisfied.  ``remove_host`` is the
eviction rebuild: the same mesh minus one slice along an axis, used by the
straggler-response controller when a host is pulled from the fleet.
Production pod topologies live in :mod:`repro.launch.mesh`; this module is the
everything-else path.
"""

from __future__ import annotations

import math
from collections.abc import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from .compat import make_mesh

__all__ = ["local_mesh", "default_axis_names", "pipeline_submeshes", "remove_host"]

_AXIS_NAMES_BY_RANK = {
    1: ("data",),
    2: ("data", "model"),
    3: ("pod", "data", "model"),
}


def default_axis_names(rank: int) -> tuple[str, ...]:
    """Conventional axis names for a mesh of the given rank."""
    if rank not in _AXIS_NAMES_BY_RANK:
        raise ValueError(
            f"no default axis names for a rank-{rank} mesh; pass axis_names "
            f"explicitly (defaults exist for ranks {sorted(_AXIS_NAMES_BY_RANK)})"
        )
    return _AXIS_NAMES_BY_RANK[rank]


def local_mesh(
    mesh_shape: Sequence[int] = (1, 1),
    axis_names: Sequence[str] | None = None,
) -> Mesh:
    """Build a mesh of ``mesh_shape`` from this process's devices.

    CPU-friendly: a ``(1, 1)`` shape on a single-CPU host yields a 1-device
    ``("data", "model")`` mesh, so the same launcher code path runs in CI and
    at scale.  Uses the first ``prod(mesh_shape)`` devices, so a smaller mesh
    than the host's device count is allowed.
    """
    shape = tuple(int(s) for s in mesh_shape)
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh_shape must be non-empty positive ints, got {mesh_shape!r}")
    names = tuple(axis_names) if axis_names is not None else default_axis_names(len(shape))
    if len(names) != len(shape):
        raise ValueError(f"axis_names {names} does not match mesh_shape {shape}")
    n_needed = math.prod(shape)
    devices = jax.devices()
    if n_needed > len(devices):
        raise ValueError(
            f"mesh_shape {shape} needs {n_needed} devices but only "
            f"{len(devices)} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_needed} for CPU dry-runs"
        )
    return make_mesh(shape, names, devices=devices[:n_needed])


def remove_host(mesh: Mesh, index: int, axis: str | None = None) -> Mesh:
    """Rebuild ``mesh`` without slice ``index`` along ``axis`` — the straggler
    eviction path.

    Surviving devices keep their relative order, so existing logical-axis
    sharding rules keep applying to the shrunk mesh; only the named axis loses
    one slice.  ``axis`` defaults to the mesh's first (host/data) axis.  A
    size-1 axis refuses the removal: a fleet cannot evict its last slice.
    """
    names = tuple(mesh.axis_names)
    axis = axis if axis is not None else names[0]
    if axis not in names:
        raise ValueError(f"mesh has no axis {axis!r}; axes are {names}")
    pos = names.index(axis)
    size = int(mesh.shape[axis])
    if size < 2:
        raise ValueError(
            f"cannot remove slice {index} from axis {axis!r} of size {size}: "
            f"a mesh cannot lose its last slice"
        )
    if not 0 <= index < size:
        raise ValueError(f"slice {index} out of range [0, {size}) on axis {axis!r}")
    devices = np.delete(np.asarray(mesh.devices), index, axis=pos)
    return Mesh(devices, names)


def pipeline_submeshes(mesh: Mesh, axis: str) -> list[Mesh]:
    """One mesh per slice along ``axis``, spanning the remaining axes.

    The pipeline-stage hook: a launcher that pipelines over ``axis`` hands
    each stage its own submesh for stage-local work (per-stage data feeds,
    per-stage checkpoint shards, restaged parameter placement after a
    :class:`~repro.dist.pipeline.StagePlan` boundary move).  Slice ``i`` of
    the returned list holds the devices of pipeline rank ``i``; each submesh
    keeps the remaining axis names and device order, so existing sharding
    rules keep applying stage-locally.  A rank-1 mesh yields single-device
    ``(1,)`` submeshes (the axis name is retained with size 1).
    """
    names = tuple(mesh.axis_names)
    if axis not in names:
        raise ValueError(f"mesh has no axis {axis!r}; axes are {names}")
    pos = names.index(axis)
    devices = np.asarray(mesh.devices)
    out: list[Mesh] = []
    for i in range(int(mesh.shape[axis])):
        stage_devices = np.take(devices, [i], axis=pos)
        if len(names) > 1:
            stage_devices = np.squeeze(stage_devices, axis=pos)
            out.append(Mesh(stage_devices, names[:pos] + names[pos + 1:]))
        else:
            out.append(Mesh(stage_devices, names))
    return out
