"""Distributed substrate: logical-axis sharding, mesh utilities, cross-process
timer reductions, and pipeline parallelism.

The package mirrors the paper's scaling story (Sec. 1: timing infrastructure
"for large-scale simulations ... distributed over many processors"): model code
annotates tensors with *logical* axis names, :mod:`repro.dist.sharding` maps
them onto physical mesh axes, and :mod:`repro.dist.stragglers` aggregates
per-host step walltimes from the timer database — the Cactus-style
cross-process timer reduction that lets a run profile itself and adapt.

Modules
-------
``sharding``   logical-axis rules -> ``PartitionSpec``/``NamedSharding``
``context``    ambient (mesh, rules) context + ``constrain`` annotations
``meshutil``   local/CI-friendly device-mesh construction + eviction rebuild
               and per-stage pipeline submeshes
``stragglers`` cross-host step-time reduction + slow-host detection
``pipeline``   GPipe forward + 1F1B training schedules, microbatch/stage plans
``compat``     shims over jax API drift (``shard_map``, ``make_mesh``)

Acting on what the reduction finds — rebalancing microbatch plans, evicting
hosts, rebuilding meshes — is orchestrated by :mod:`repro.adapt`.
"""

from .context import constrain, current_sharding, use_sharding
from .meshutil import local_mesh, pipeline_submeshes, remove_host
from .pipeline import MicrobatchPlan, PipelineStep, StagePlan, phase_ticks, pipeline_step
from .sharding import DEFAULT_RULES, FSDP_RULES, Axes, ShardingRules, spec_for, tree_shardings
from .stragglers import LocalTransport, StragglerDetector, StragglerReport


__all__ = [
    "Axes",
    "ShardingRules",
    "DEFAULT_RULES",
    "FSDP_RULES",
    "spec_for",
    "tree_shardings",
    "use_sharding",
    "current_sharding",
    "constrain",
    "local_mesh",
    "pipeline_submeshes",
    "remove_host",
    "MicrobatchPlan",
    "PipelineStep",
    "StagePlan",
    "phase_ticks",
    "pipeline_step",
    "LocalTransport",
    "StragglerDetector",
    "StragglerReport",
]
