"""Mesh/membership coordinate derivations.

The launcher used to hard-code ``stage_for_host={0: 0}``; with a live
membership the mapping must follow the fleet: :func:`stage_for_host` assigns
sorted member hosts to pipeline stages in contiguous blocks — host ``i`` of
``n`` on an ``(S, D)`` pipeline x data mesh owns pipeline coordinate
``i * S // n`` — so stage ownership is a pure function of (membership, stage
count) and re-derives correctly after every join or evict.
"""

from __future__ import annotations

from collections.abc import Iterable

__all__ = ["data_parallel_rank", "stage_for_host"]


def stage_for_host(hosts: Iterable[int], n_stages: int) -> dict[int, int]:
    """{host: owned pipeline stage} — contiguous blocks over sorted hosts.

    Every stage is owned (when ``len(hosts) >= n_stages``) and ownership is
    balanced: ``n`` hosts split into ``n_stages`` runs whose sizes differ by
    at most one.  With fewer hosts than stages, each host owns the first
    stage of its block (the remaining stages ride along in-process, as the
    single-host pipeline path always has).
    """
    ordered = sorted(int(h) for h in hosts)
    if n_stages <= 0 or not ordered:
        return {}
    n = len(ordered)
    return {
        h: min(i * n_stages // n, n_stages - 1) for i, h in enumerate(ordered)
    }


def data_parallel_rank(hosts: Iterable[int], host: int) -> int:
    """``host``'s dense data-parallel coordinate within the sorted membership
    (the index a collective would use, stable under sparse host ids)."""
    ordered = sorted(int(h) for h in hosts)
    try:
        return ordered.index(int(host))
    except ValueError:
        raise ValueError(f"host {host} not in membership {ordered}") from None
