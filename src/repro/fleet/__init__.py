"""Real multi-process fleets: transport, elastic membership, payback gates.

The simulated fleet (:class:`repro.adapt.SimulatedFleet`) proved the
measure→decide→migrate loop on one CPU; this package runs the same loop over
**real subprocess ranks**:

* :mod:`~repro.fleet.store` — the file-backed rendezvous substrate (atomic
  JSON keys + append-only JSONL logs, no external services);
* :mod:`~repro.fleet.transport` — :class:`FleetTransport`, the cross-process
  ``publish``/``gather`` implementation of the
  :class:`~repro.dist.stragglers.LocalTransport` surface, with heartbeats and
  epoch fencing so a partitioned or killed rank is *detected*, never assumed;
* :mod:`~repro.fleet.membership` — :class:`Membership` (the epoch-fenced host
  registry over the shared :class:`~repro.dist.pipeline.MicrobatchPlan`) and
  :class:`FleetController` (mid-run joins earn share, heartbeat-expired hosts
  leave through the checkpoint-before-evict barrier);
* :mod:`~repro.fleet.payback` — :class:`ReshardCost` (measured save+restore
  seconds) and :class:`PaybackPolicy` (evict/join only when the projected win
  over the horizon covers the re-shard cost; every skip is an
  ``ADAPT/fleet::defer_reshard`` row);
* :mod:`~repro.fleet.topology` — stage ownership as a pure function of
  (membership, stage count);
* :mod:`~repro.fleet.worker` / :mod:`~repro.fleet.launch` — the numpy-only
  rank main and the multi-process launcher
  (``python -m repro.fleet.launch --hosts N``).

Importing this package stays jax-free (worker startup must be fast); the
launcher imports the jax-adjacent control plane lazily at call time.
"""

from .store import FileStore
from .topology import data_parallel_rank, stage_for_host
from .transport import FleetTransport

__all__ = [
    "FileStore",
    "FleetController",
    "FleetTransport",
    "Membership",
    "PaybackPolicy",
    "ReshardCost",
    "data_parallel_rank",
    "stage_for_host",
]

#: control-plane classes resolved lazily (PEP 562): they import repro.adapt,
#: which drags in jax — the worker subprocess must never pay that at spawn
_LAZY = {
    "PaybackPolicy": "payback",
    "ReshardCost": "payback",
    "Membership": "membership",
    "FleetController": "membership",
}


def __getattr__(name: str):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{module}", __name__), name)
