"""Payback-aware migration: is the re-shard worth it?

Evicting a straggler or admitting a newcomer is not free — the fleet must
durably checkpoint, re-apportion every plan, and rebuild its mesh before the
first post-change step runs.  The Cactus Worm experiments and AdaptCheck both
frame this the same way: move work only when the projected win over a payback
horizon exceeds the cost of moving it.

:class:`ReshardCost` carries the cost side in seconds — seeded from the
committed checkpoint benchmark baselines (``benchmarks/baselines/
checkpoint.json``: measured ``save_sync`` + ``restore_latest`` per-call times)
and updated with live-measured save/restore seconds as the run observes its
own checkpoints (EWMA, so a run on slower disks converges to its own truth).

:class:`PaybackPolicy` turns that into the two gates the control plane calls:

* :meth:`evict_gate` plugs into ``StragglerResponse(reshard_gate=...)`` — the
  projected win of dropping a straggler is the per-step seconds the fleet
  median waits on it, integrated over the horizon;
* :meth:`join_gate` guards mid-run admissions — the projected win of one more
  host is the per-step fleet time recovered by spreading the same microbatches
  wider, integrated over the same horizon.

Either gate returns ``None`` (payback covers the cost: proceed) or the
``ADAPT/fleet::defer_reshard`` :class:`ControlAction` describing exactly why
the move was skipped — every skip is logged, none is silent.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

from ..adapt.controller import ControlAction
from ..dist.stragglers import StragglerReport

__all__ = ["PaybackPolicy", "ReshardCost"]

#: fallback seconds when no baseline file is reachable (the committed
#: tiny-scale CPU numbers, rounded up — a conservative floor, not a model)
_FALLBACK_SAVE_S = 0.006
_FALLBACK_RESTORE_S = 0.003

_BASELINE_ROWS = {"ckpt/save_sync": "save_s", "ckpt/restore_latest": "restore_s"}


def _default_baseline_path() -> str:
    # repo layout: src/repro/fleet/payback.py -> benchmarks/baselines/...
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(
        here, "..", "..", "..", "benchmarks", "baselines", "checkpoint.json"
    )


@dataclass
class ReshardCost:
    """Seconds one membership change costs the fleet, by phase."""

    save_s: float = _FALLBACK_SAVE_S
    restore_s: float = _FALLBACK_RESTORE_S
    #: plan re-apportionment + mesh rebuild (usually dwarfed by the I/O)
    rebuild_s: float = 0.0
    #: EWMA weight for live observations folded in via :meth:`observe`
    ewma: float = 0.5

    def total(self) -> float:
        return self.save_s + self.restore_s + self.rebuild_s

    @classmethod
    def from_baseline(cls, path: str | None = None) -> ReshardCost:
        """Seed from the measured checkpoint benchmark baselines (µs/call
        rows); falls back to the conservative defaults when unreadable."""
        path = path or _default_baseline_path()
        kwargs: dict[str, float] = {}
        try:
            with open(path, encoding="utf-8") as f:
                payload = json.load(f)
            for row in payload.get("rows", ()):
                field = _BASELINE_ROWS.get(row.get("name"))
                if field is not None:
                    kwargs[field] = float(row["us_per_call"]) * 1e-6
        except (OSError, ValueError, KeyError, TypeError):
            pass
        return cls(**kwargs)

    def observe(
        self, save_s: float | None = None, restore_s: float | None = None
    ) -> None:
        """Fold a live-measured save/restore duration into the model."""
        if save_s is not None and save_s > 0.0:
            self.save_s += self.ewma * (float(save_s) - self.save_s)
        if restore_s is not None and restore_s > 0.0:
            self.restore_s += self.ewma * (float(restore_s) - self.restore_s)


class PaybackPolicy:
    """The two membership gates, parameterized by one horizon.

    Parameters
    ----------
    cost:
        The :class:`ReshardCost` both gates amortize against.
    horizon_steps:
        How many future steps a win is credited over before it must cover the
        re-shard cost.  ``0`` means "no future to amortize against" — every
        optional move defers (useful to demonstrate/drill the defer path).
    min_hosts:
        Joins that bring the fleet up to this size bypass the gate: a fleet
        below its provisioned size is rebuilding, not speculating.
    """

    def __init__(
        self, cost: ReshardCost, *, horizon_steps: int = 50, min_hosts: int = 1
    ) -> None:
        if horizon_steps < 0:
            raise ValueError(f"horizon_steps must be >= 0, got {horizon_steps}")
        self.cost = cost
        self.horizon_steps = int(horizon_steps)
        self.min_hosts = int(min_hosts)
        #: defer decisions taken, by reason ("evict" / "join")
        self.defers: dict[str, int] = {"evict": 0, "join": 0}

    # -- gates -------------------------------------------------------------------
    def _defer(
        self, step: int, reason: str, host: int, win_per_step: float
    ) -> ControlAction:
        self.defers[reason] = self.defers.get(reason, 0) + 1
        projected = win_per_step * self.horizon_steps
        return ControlAction(
            step=step,
            controller="fleet",
            trigger=f"DIST/host{host}::step",
            action="defer_reshard",
            detail={
                "reason": reason,
                "host": host,
                "win_per_step_s": round(win_per_step, 6),
                "projected_win_s": round(projected, 6),
                "reshard_cost_s": round(self.cost.total(), 6),
                "horizon_steps": self.horizon_steps,
            },
        )

    def evict_gate(
        self, step: int, host: int, report: StragglerReport, slowdown: float
    ) -> ControlAction | None:
        """``StragglerResponse.reshard_gate`` hook: ``None`` lets the eviction
        proceed; otherwise the returned defer action is recorded instead.

        The win of shedding a straggler is the seconds per step the fleet
        spends waiting past its median on that host.
        """
        win_per_step = max(
            report.host_means.get(host, report.median) - report.median, 0.0
        )
        if win_per_step * self.horizon_steps > self.cost.total():
            return None
        return self._defer(step, "evict", host, win_per_step)

    def join_gate(
        self, step: int, host: int, n_active: int, mean_step_s: float
    ) -> ControlAction | None:
        """``None`` admits the join; otherwise the defer action.

        The win of one more host is the per-step time recovered by spreading
        the same work one way wider: ``mean_step_s * (1 / (n + 1))``.
        """
        if n_active < self.min_hosts:
            return None  # rebuilding to provisioned size is never speculative
        win_per_step = max(mean_step_s, 0.0) / (n_active + 1)
        if win_per_step * self.horizon_steps > self.cost.total():
            return None
        return self._defer(step, "join", host, win_per_step)
