"""Multi-process fleet launcher: real subprocess ranks under the control plane.

``python -m repro.fleet.launch --hosts 2 --steps 40`` spawns ``--hosts`` real
worker processes (:mod:`repro.fleet.worker`) over a file-backed rendezvous
store and runs the controller loop in this process: the straggler detector
gathers every rank's step times through the epoch-fenced
:class:`~repro.fleet.transport.FleetTransport`, the
:class:`~repro.adapt.stragglers.StragglerResponse` rebalances/evicts through
the checkpoint-before-evict barrier and the payback gate, and the
:class:`~repro.fleet.membership.FleetController` admits mid-run joins and
evicts heartbeat-expired ranks.

The event script (``--join-at STEP:HOST``, ``--kill-at``, ``--hang-at``,
``--cont-at``, ``--slow-at STEP:HOST:FACTOR``) drives real process-level
faults — SIGKILL, SIGSTOP/SIGCONT, pacing throttles — at controller poll
steps, which is what the tier-1 smoke and the nightly drill exercise.

The re-shard cost model is **measured, not assumed**: it seeds from the
committed checkpoint benchmark baselines and then folds in the startup durable
save + restore this very launcher performs, so the payback gate amortizes
against this machine's actual checkpoint latency.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..adapt.checkpoint import CheckpointControl
from ..adapt.controller import ControlLoop
from ..adapt.stragglers import StragglerResponse
from ..checkpoint import CheckpointManager
from ..core.adaptive import AdaptiveCheckpointPolicy
from ..core.timers import TimerDB
from ..dist.pipeline import MicrobatchPlan
from ..dist.stragglers import StragglerDetector
from ..monitor.export import MetricsExporter
from ..monitor.server import MonitorServer
from .membership import FleetController, Membership
from .payback import PaybackPolicy, ReshardCost
from .store import FileStore
from .transport import FleetTransport

__all__ = ["FleetSettings", "run_fleet"]


@dataclass
class FleetSettings:
    """Everything one fleet run needs; the CLI populates one of these."""

    hosts: int = 2
    steps: int = 40
    n_micro: int = 8
    step_floor_s: float = 0.02
    poll_interval_s: float = 0.1
    liveness_timeout_s: float = 1.0
    horizon_steps: int = 50
    extra_reshard_cost_s: float = 0.0
    seed: int = 0
    pipeline_stages: int = 0
    rendezvous: str | None = None
    monitor_port: int | None = None
    metrics_textfile: str | None = None
    snapshot_every: int = 5
    #: scripted events, each a (poll step, host) pair
    join_at: list[tuple[int, int]] = field(default_factory=list)
    kill_at: list[tuple[int, int]] = field(default_factory=list)
    hang_at: list[tuple[int, int]] = field(default_factory=list)
    cont_at: list[tuple[int, int]] = field(default_factory=list)
    #: (poll step, host, pacing factor)
    slow_at: list[tuple[int, int, float]] = field(default_factory=list)


def _worker_env() -> dict[str, str]:
    """Subprocess env with the repo's ``src`` on PYTHONPATH (the launcher may
    itself run from a checkout rather than an installed package)."""
    src = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    existing = env.get("PYTHONPATH", "")
    if src not in existing.split(os.pathsep):
        env["PYTHONPATH"] = f"{src}{os.pathsep}{existing}" if existing else src
    return env


def _spawn_worker(
    root: str, host: int, settings: FleetSettings, *, join: bool = False
) -> subprocess.Popen:
    cmd = [
        sys.executable,
        "-m",
        "repro.fleet.worker",
        "--root",
        root,
        "--host",
        str(host),
        "--step-floor-s",
        str(settings.step_floor_s),
        "--seed",
        str(settings.seed),
    ]
    if join:
        cmd.append("--join")
    return subprocess.Popen(
        cmd,
        env=_worker_env(),
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )


def run_fleet(settings: FleetSettings) -> dict[str, Any]:
    """Run one fleet: spawn ranks, drive the control loop, return the journal."""
    if settings.hosts < 1:
        raise ValueError(f"need at least one host, got {settings.hosts}")
    own_dir = settings.rendezvous is None
    root = settings.rendezvous or tempfile.mkdtemp(prefix="repro-fleet-")
    store = FileStore(root)

    db = TimerDB()
    plan = MicrobatchPlan.equal(range(settings.hosts), settings.n_micro)
    membership = Membership(
        store,
        plan,
        n_stages=settings.pipeline_stages,
        liveness_timeout=settings.liveness_timeout_s,
    )
    transport = FleetTransport(store, members_fn=membership.members_fn)
    detector = StragglerDetector(
        n_hosts=settings.hosts,
        window=4,
        threshold=2.0,
        db=db,
        transport=transport,
    )

    # -- measured re-shard cost: baseline seed + live save/restore ----------
    cost = ReshardCost.from_baseline()
    cost.rebuild_s += settings.extra_reshard_cost_s
    manager = CheckpointManager(
        os.path.join(root, "ckpt"), keep_n=3, synchronous=True, fsync=False
    )
    ckpt = CheckpointControl(AdaptiveCheckpointPolicy(mode="adaptive"))

    def durable_save(step: int) -> float:
        t0 = time.monotonic()
        hosts = membership.hosts
        manager.save(
            step,
            {
                "hosts": np.asarray(hosts, dtype=np.int64),
                "weights": np.asarray([plan.weights[h] for h in hosts]),
                "epoch": np.asarray([membership.epoch], dtype=np.int64),
            },
            metadata={"epoch": membership.epoch},
        )
        manager.wait()
        seconds = time.monotonic() - t0
        cost.observe(save_s=seconds)
        return seconds

    ckpt.bind_durable_save(durable_save)
    ckpt.start_run()
    # one startup save + restore, timed: the payback gate amortizes against
    # this machine's real checkpoint latency, not just the committed baseline
    durable_save(0)
    t0 = time.monotonic()
    manager.restore_latest()
    cost.observe(restore_s=time.monotonic() - t0)

    payback = PaybackPolicy(
        cost, horizon_steps=settings.horizon_steps, min_hosts=settings.hosts
    )
    response = StragglerResponse(
        detector,
        plan,
        check_every=1,
        confirm_after=2,
        evict_after=3,
        min_weight=0.25,
        on_evict=lambda host, report: membership.remove(host),
        evict_barrier=ckpt.evict_barrier,
        reshard_gate=payback.evict_gate,
    )
    fleet = FleetController(
        membership,
        transport,
        response,
        payback=payback,
        evict_barrier=ckpt.evict_barrier,
    )
    loop = ControlLoop(db=db)
    loop.register(response)
    loop.register(fleet)

    exporter = MetricsExporter(
        db,
        control_loop=loop,
        detector=detector,
        checkpoint_fn=manager.status_payload,
        fleet_fn=fleet.status_payload,
    )
    server = None
    if settings.monitor_port is not None:
        server = MonitorServer(
            settings.monitor_port,
            db,
            status_fn=lambda: {"epoch": membership.epoch, "hosts": membership.hosts},
            checkpoint_fn=manager.status_payload,
            fleet_fn=fleet.status_payload,
            exporter=exporter,
        )
        server.start()

    # -- spawn the initial ranks and index the event script -----------------
    procs: dict[int, subprocess.Popen] = {
        h: _spawn_worker(root, h, settings) for h in range(settings.hosts)
    }
    def _by_step(events):
        out: dict[int, list] = {}
        for step, *rest in events:
            out.setdefault(step, []).append(rest)
        return out

    joins = _by_step(settings.join_at)
    kills = _by_step(settings.kill_at)
    hangs = _by_step(settings.hang_at)
    conts = _by_step(settings.cont_at)
    slows = _by_step(settings.slow_at)

    def _signal(host: int, sig: int) -> None:
        proc = procs.get(host)
        if proc is None:
            return
        try:  # the target may already be dead (a drill can kill then hang)
            proc.send_signal(sig)
        except (ProcessLookupError, OSError):
            pass

    snapshots: list[dict[str, Any]] = []

    def snap(step: int) -> None:
        # in-process truth first, then the scrape: check_snapshots compares
        # the wire view against the decision log taken just before it
        actions = dict(loop.summary()["action_counts"])
        snapshots.append(
            {"step": step, "actions": actions, "exposition": exporter.render()}
        )

    snap(-1)
    try:
        for step in range(settings.steps):
            time.sleep(settings.poll_interval_s)
            for (host,) in joins.get(step, ()):
                procs[host] = _spawn_worker(root, host, settings, join=True)
            for (host,) in kills.get(step, ()):
                _signal(host, signal.SIGKILL)
            for (host,) in hangs.get(step, ()):
                _signal(host, signal.SIGSTOP)
            for (host,) in conts.get(step, ()):
                _signal(host, signal.SIGCONT)
            for host, factor in slows.get(step, ()):
                store.put(f"faults/{host}", {"slow": factor})
            loop.poll(step)
            if settings.snapshot_every and (step + 1) % settings.snapshot_every == 0:
                snap(step)
    finally:
        store.put("shutdown", {"t": time.time(), "step": settings.steps})
        for host in procs:
            # a SIGSTOP'd rank cannot see the shutdown key; resume it first
            _signal(host, signal.SIGCONT)
        for host, proc in procs.items():
            try:
                proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5.0)
        if server is not None:
            server.stop()

    snap(settings.steps)
    if settings.metrics_textfile:
        exporter.write_textfile(settings.metrics_textfile)

    finals = {
        key.rsplit("/", 1)[1]: value for key, value in store.scan("final").items()
    }
    summary = {
        "root": root,
        "own_rendezvous": own_dir,
        "steps": settings.steps,
        "epoch": membership.epoch,
        "hosts": membership.hosts,
        "shares": plan.shares() if plan.weights else {},
        "joins_total": fleet.joins_total,
        "leaves_total": fleet.leaves_total,
        "deferred_leaves": fleet.deferred_leaves,
        "reshard_defers": dict(payback.defers),
        "deferred_reshards": response.deferred_reshards,
        "stale_rejected": transport.stale_rejected,
        "barrier_saves": ckpt.barrier_saves,
        "reshard_cost_s": round(cost.total(), 6),
        "action_counts": loop.summary()["action_counts"],
        "actions": [a.describe() for a in loop.actions],
        "finals": finals,
        "snapshots": snapshots,
    }
    return summary


def _parse_events(values: list[str], with_arg: bool = False) -> list[tuple]:
    out: list[tuple] = []
    for value in values or []:
        parts = value.split(":")
        want = 3 if with_arg else 2
        if len(parts) != want:
            shape = "STEP:HOST:FACTOR" if with_arg else "STEP:HOST"
            raise SystemExit(f"bad event {value!r}; expected {shape}")
        if with_arg:
            out.append((int(parts[0]), int(parts[1]), float(parts[2])))
        else:
            out.append((int(parts[0]), int(parts[1])))
    return out


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        description="Run a real multi-process fleet over the rendezvous store"
    )
    parser.add_argument("--hosts", type=int, default=2)
    parser.add_argument("--steps", type=int, default=40, help="controller polls")
    parser.add_argument("--n-micro", type=int, default=8)
    parser.add_argument("--step-floor-s", type=float, default=0.02)
    parser.add_argument("--poll-interval-s", type=float, default=0.1)
    parser.add_argument("--liveness-timeout-s", type=float, default=1.0)
    parser.add_argument("--horizon-steps", type=int, default=50)
    parser.add_argument(
        "--reshard-cost-s",
        type=float,
        default=0.0,
        help="extra rebuild seconds added on top of the measured save+restore",
    )
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--pipeline-stages", type=int, default=0)
    parser.add_argument("--rendezvous", default=None)
    parser.add_argument("--monitor-port", type=int, default=None)
    parser.add_argument("--metrics-textfile", default=None)
    parser.add_argument("--join-at", action="append", metavar="STEP:HOST")
    parser.add_argument("--kill-at", action="append", metavar="STEP:HOST")
    parser.add_argument("--hang-at", action="append", metavar="STEP:HOST")
    parser.add_argument("--cont-at", action="append", metavar="STEP:HOST")
    parser.add_argument("--slow-at", action="append", metavar="STEP:HOST:FACTOR")
    parser.add_argument("--json", action="store_true", help="print the full journal")
    args = parser.parse_args(argv)

    settings = FleetSettings(
        hosts=args.hosts,
        steps=args.steps,
        n_micro=args.n_micro,
        step_floor_s=args.step_floor_s,
        poll_interval_s=args.poll_interval_s,
        liveness_timeout_s=args.liveness_timeout_s,
        horizon_steps=args.horizon_steps,
        extra_reshard_cost_s=args.reshard_cost_s,
        seed=args.seed,
        pipeline_stages=args.pipeline_stages,
        rendezvous=args.rendezvous,
        monitor_port=args.monitor_port,
        metrics_textfile=args.metrics_textfile,
        join_at=_parse_events(args.join_at),
        kill_at=_parse_events(args.kill_at),
        hang_at=_parse_events(args.hang_at),
        cont_at=_parse_events(args.cont_at),
        slow_at=_parse_events(args.slow_at, with_arg=True),
    )
    summary = run_fleet(settings)
    if args.json:
        print(json.dumps(summary, indent=2, default=str))
    else:
        print(
            f"fleet done: epoch={summary['epoch']} hosts={summary['hosts']} "
            f"joins={summary['joins_total']} leaves={summary['leaves_total']} "
            f"defers={summary['reshard_defers']} "
            f"stale_rejected={summary['stale_rejected']}"
        )
        for line in summary["actions"]:
            print("  " + line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
