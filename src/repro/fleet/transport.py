"""Cross-process step-time transport over the rendezvous store.

:class:`FleetTransport` implements the exact two-call surface the straggler
reduction already consumes (:class:`repro.dist.stragglers.LocalTransport`:
``publish`` / ``gather`` / ``drop_host`` / ``dropped``) — so the detector, the
response policy, and every test built on the in-process transport work
unchanged when the samples start arriving from real subprocess ranks.

Wire format: worker rank ``h`` appends ``{"e": epoch, "s": seconds}`` records
to the ``samples/h`` log; the controller side drains each log from a tracked
byte offset.  Two defenses make a partitioned or killed rank *detected* rather
than assumed:

* **epoch fencing** — every sample carries the membership epoch the worker
  believed current when it published.  The gather side rejects records from
  hosts outside the current membership and records stamped before the host's
  admission epoch (a stale incarnation of a reused id); every rejection counts
  in :attr:`FleetTransport.stale_rejected`.  A fenced-out rank can keep
  writing — its bytes land, its samples never reach the reduction.
* **heartbeats + liveness** — each worker runs a daemon heartbeat thread
  (:meth:`start_heartbeat`) refreshing ``beat/h``; the membership layer evicts
  hosts whose beat age exceeds the liveness timeout.  A SIGSTOP'd rank stops
  beating and is fenced the same as a SIGKILL'd one.
"""

from __future__ import annotations

import os
import threading
import time

from .store import FileStore

__all__ = ["FleetTransport"]


class FleetTransport:
    """File-store-backed ``publish``/``gather`` transport with epoch fencing.

    One class serves both sides.  A worker constructs it with its ``host`` id
    and calls :meth:`publish` (stamping :attr:`epoch`, which the worker
    refreshes from the membership record each step) plus
    :meth:`start_heartbeat`.  The controller constructs it with a
    ``members_fn`` — ``() -> (epoch, {host: joined_epoch})`` from the live
    :class:`~repro.fleet.membership.Membership` — and hands it to the
    :class:`~repro.dist.stragglers.StragglerDetector` as its transport.
    """

    def __init__(
        self,
        store: FileStore,
        *,
        host: int | None = None,
        members_fn=None,
        heartbeat_interval: float = 0.5,
    ) -> None:
        self.store = store
        self.host = host
        self.members_fn = members_fn
        self.heartbeat_interval = heartbeat_interval
        #: worker side: the membership epoch stamped on the next publish
        self.epoch = 0
        #: controller side: samples rejected by the epoch fence
        self.stale_rejected = 0
        self._offsets: dict[int, int] = {}
        self._dropped: set[int] = set()
        self._hb_stop: threading.Event | None = None
        self._hb_thread: threading.Thread | None = None

    # -- worker side ------------------------------------------------------------
    def publish(self, host: int, seconds: float) -> None:
        """Append one step walltime, stamped with the current :attr:`epoch`."""
        self.store.append(
            f"samples/{int(host)}", {"e": int(self.epoch), "s": float(seconds)}
        )

    def heartbeat(self, host: int | None = None) -> None:
        h = self.host if host is None else host
        self.store.put(f"beat/{int(h)}", {"t": time.time(), "pid": os.getpid()})

    def start_heartbeat(self, host: int | None = None) -> None:
        """Run :meth:`heartbeat` on a daemon thread every interval.  A stopped
        (SIGSTOP) process stops the thread with it — exactly the liveness
        signal the membership layer needs."""
        if self._hb_thread is not None:
            return
        h = self.host if host is None else host
        stop = threading.Event()

        def beat() -> None:
            while not stop.is_set():
                try:
                    self.heartbeat(h)
                except OSError:
                    pass  # rendezvous dir tearing down at shutdown
                stop.wait(self.heartbeat_interval)

        self._hb_stop = stop
        self._hb_thread = threading.Thread(target=beat, daemon=True)
        self._hb_thread.start()

    def stop_heartbeat(self) -> None:
        if self._hb_stop is not None:
            self._hb_stop.set()
            self._hb_thread.join(timeout=2.0)
            self._hb_stop = None
            self._hb_thread = None

    # -- controller side --------------------------------------------------------
    def _fence(self) -> tuple[int, dict[int, int]]:
        if self.members_fn is None:
            return self.epoch, {}
        epoch, joined = self.members_fn()
        return int(epoch), {int(h): int(e) for h, e in joined.items()}

    def gather(self) -> dict[int, list[float]]:
        """Drain every sample log past its offset; fence, then deliver.

        A sample survives the fence iff its host is in the *current*
        membership, has not been dropped, and the stamped epoch is at or
        after the host's admission epoch.  Everything else increments
        :attr:`stale_rejected` — the partitioned-rank detection signal.
        """
        epoch, joined = self._fence()
        out: dict[int, list[float]] = {}
        for log in self.store.logs("samples"):
            try:
                host = int(log.rsplit("/", 1)[1])
            except ValueError:
                continue
            records, self._offsets[host] = self.store.read_log(
                log, self._offsets.get(host, 0)
            )
            for rec in records:
                stamped = int(rec.get("e", -1))
                if (
                    host in self._dropped
                    or (self.members_fn is not None and host not in joined)
                    or (self.members_fn is not None and stamped < joined.get(host, 0))
                ):
                    self.stale_rejected += 1
                    continue
                out.setdefault(host, []).append(float(rec.get("s", 0.0)))
        return out

    def drop_host(self, host: int) -> None:
        """Stop accepting samples from ``host`` (eviction path)."""
        self._dropped.add(int(host))

    @property
    def dropped(self) -> frozenset:
        return frozenset(self._dropped)
