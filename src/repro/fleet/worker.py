"""One fleet rank: a real subprocess training worker over the rendezvous store.

``python -m repro.fleet.worker --root DIR --host H`` runs the loop every rank
in a :mod:`repro.fleet.launch` fleet executes:

1. (``--join``) write a ``join/<host>`` request, then wait to be admitted;
2. start the heartbeat thread (``beat/<host>``), the liveness signal;
3. each step: re-read the membership record — refresh the publish epoch, pick
   up the current microbatch ``share`` (this is how a retarget reaches the
   rank), and **discover fencing**: a host absent from the record has been
   evicted and exits cleanly instead of computing into the void;
4. run ``share`` SGD microbatches of the shared least-squares problem, pace to
   ``share x step_floor_s`` (x any injected ``faults/<host>`` slow factor — the
   drill's straggler lever), and publish the measured step walltime stamped
   with the epoch;
5. on the ``shutdown`` key (or ``--max-steps``): write a ``final/<host>``
   result record and exit 0.

Deliberately **numpy-only** — no jax, no repro.dist import — so a rank spawns
in well under a second and a mid-run join costs join-latency, not
compile-latency.  The controller side (which owns the timer DB, detector, and
control loop) lives in :mod:`repro.fleet.launch`.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Any

import numpy as np

from .store import FileStore
from .transport import FleetTransport

__all__ = ["run_worker"]

#: exit statuses written into the final/<host> record
_STATUS_DONE = "done"  # saw shutdown (or hit --max-steps)
_STATUS_FENCED = "fenced"  # discovered own eviction in the membership record

_MEMBERSHIP_KEY = "membership"


def _make_problem(seed: int, dim: int = 8, n_rows: int = 64):
    """The shared synthetic least-squares problem every rank trains on —
    seeded identically, so any rank's loss trajectory is comparable."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_rows, dim))
    w_true = rng.standard_normal(dim)
    y = x @ w_true + 0.01 * rng.standard_normal(n_rows)
    return x, y


def run_worker(
    root: str,
    host: int,
    *,
    join: bool = False,
    step_floor_s: float = 0.02,
    seed: int = 0,
    heartbeat_interval: float = 0.25,
    poll_interval: float = 0.02,
    max_steps: int = 0,
    admit_timeout_s: float = 30.0,
) -> dict[str, Any]:
    """Run the rank loop; returns the final record also written to the store."""
    store = FileStore(root)
    transport = FleetTransport(
        store, host=host, heartbeat_interval=heartbeat_interval
    )
    transport.start_heartbeat()
    if join:
        store.put(
            f"join/{host}",
            {"host": host, "pid": os.getpid(), "requested": time.time()},
        )

    # -- wait for admission (initial members are already in the record) -------
    deadline = time.monotonic() + admit_timeout_s
    status = _STATUS_DONE
    record = None
    while True:
        if store.get("shutdown") is not None:
            record = None
            break
        record = store.get(_MEMBERSHIP_KEY)
        if record is not None and str(host) in record.get("hosts", {}):
            break
        if time.monotonic() > deadline:
            record = None
            status = "admit_timeout"
            break
        time.sleep(poll_interval)

    x, y = _make_problem(seed)
    w = np.zeros(x.shape[1])
    lr = 0.01
    steps = 0
    loss = float(0.5 * np.mean((x @ w - y) ** 2))

    while record is not None:
        if store.get("shutdown") is not None:
            break
        record = store.get(_MEMBERSHIP_KEY)
        entry = (record or {}).get("hosts", {}).get(str(host))
        if entry is None:
            # fenced out: evicted (or the record vanished) — exit cleanly
            status = _STATUS_FENCED
            break
        transport.epoch = int(record.get("epoch", 0))
        share = max(int(entry.get("share", 1)), 1)
        t0 = time.monotonic()
        for _ in range(share):  # one SGD micro-step per assigned microbatch
            grad = x.T @ (x @ w - y) / len(y)
            w -= lr * grad
        loss = float(0.5 * np.mean((x @ w - y) ** 2))
        # pace the step so walltime tracks assigned work (x injected slowdown)
        fault = store.get(f"faults/{host}") or {}
        factor = max(float(fault.get("slow", 1.0)), 0.0)
        target = step_floor_s * share * (factor if factor > 0 else 1.0)
        elapsed = time.monotonic() - t0
        if elapsed < target:
            time.sleep(target - elapsed)
        transport.publish(host, time.monotonic() - t0)
        steps += 1
        if max_steps and steps >= max_steps:
            break

    transport.stop_heartbeat()
    final = {
        "host": host,
        "status": status,
        "steps": steps,
        "loss": loss,
        "epoch": transport.epoch,
        "pid": os.getpid(),
    }
    store.put(f"final/{host}", final)
    return final


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--root", required=True, help="rendezvous store directory")
    parser.add_argument("--host", type=int, required=True, help="this rank's host id")
    parser.add_argument(
        "--join",
        action="store_true",
        help="request mid-run admission instead of assuming initial membership",
    )
    parser.add_argument("--step-floor-s", type=float, default=0.02)
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--heartbeat-interval", type=float, default=0.25)
    parser.add_argument("--max-steps", type=int, default=0, help="0 = until shutdown")
    args = parser.parse_args(argv)
    final = run_worker(
        args.root,
        args.host,
        join=args.join,
        step_floor_s=args.step_floor_s,
        seed=args.seed,
        heartbeat_interval=args.heartbeat_interval,
        max_steps=args.max_steps,
    )
    return 0 if final["status"] in (_STATUS_DONE, _STATUS_FENCED) else 1


if __name__ == "__main__":
    sys.exit(main())
