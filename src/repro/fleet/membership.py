"""Elastic membership: the epoch-fenced host registry and its controller.

:class:`Membership` owns the authoritative *who-is-in-the-fleet* record: the
shared :class:`~repro.dist.pipeline.MicrobatchPlan` (the same object the
straggler response mutates), each member's admission epoch, and the
monotonically increasing **membership epoch** that fences every transition.
Each change re-apportions microbatch shares in place (``MicrobatchPlan.
retarget`` — PR 7's N→M machinery: survivors keep their learned weights,
newcomers enter at the carried mean), re-derives stage ownership
(:func:`~repro.fleet.topology.stage_for_host`), and atomically publishes the
new record to the rendezvous store, where every worker reads its share and a
fenced-out rank discovers it is gone.

:class:`FleetController` is the :class:`~repro.adapt.controller.Controller`
that drives transitions from the control loop, in this order each poll:

1. **leaves** — members whose heartbeat age exceeds the liveness timeout are
   evicted through the checkpoint-before-evict barrier (a ``None`` barrier
   verdict defers the leave to the next poll; the dead host stays fenced-out
   of gather either way once removed).  Rows: ``ADAPT/checkpoint::
   before_evict`` then ``ADAPT/fleet::leave``.
2. **joins** — pending join requests (``join/<host>`` keys written by
   workers) pass through the payback gate: an admission that does not pay for
   its re-shard within the horizon is skipped with an ``ADAPT/fleet::
   defer_reshard`` row and retried next poll; an admitted host earns share
   immediately (``ADAPT/fleet::join``).  A duplicate join of a present member
   is acknowledged idempotently — no second row, no epoch bump.
"""

from __future__ import annotations

import statistics
import time
from collections.abc import Callable
from typing import Any

from ..adapt.controller import ControlAction, Measurement
from ..adapt.stragglers import StragglerResponse
from ..dist.pipeline import MicrobatchPlan
from ..dist.stragglers import StragglerReport
from .payback import PaybackPolicy
from .store import FileStore
from .topology import stage_for_host
from .transport import FleetTransport

__all__ = ["FleetController", "Membership"]

#: the store key workers poll for their assignment + fence
MEMBERSHIP_KEY = "membership"


class Membership:
    """Controller-side membership state over the shared microbatch plan."""

    def __init__(
        self,
        store: FileStore,
        plan: MicrobatchPlan,
        *,
        n_stages: int = 0,
        liveness_timeout: float = 3.0,
        clock: Callable[[], float] = time.time,
    ) -> None:
        self.store = store
        self.plan = plan
        self.n_stages = int(n_stages)
        self.liveness_timeout = float(liveness_timeout)
        self.clock = clock
        self.epoch = 1
        #: {host: epoch at which the host was admitted} — the gather fence
        self.joined_epoch: dict[int, int] = {h: 1 for h in plan.weights}
        self.publish()

    # -- views -------------------------------------------------------------------
    @property
    def hosts(self) -> list[int]:
        return sorted(self.plan.weights)

    def members_fn(self) -> tuple[int, dict[int, int]]:
        """The fence view :class:`~repro.fleet.transport.FleetTransport`
        gathers against: (current epoch, {host: admission epoch})."""
        return self.epoch, dict(self.joined_epoch)

    def stage_map(self) -> dict[int, int]:
        return stage_for_host(self.hosts, self.n_stages)

    # -- transitions -------------------------------------------------------------
    def publish(self) -> None:
        """Atomically write the record every worker steers by."""
        shares = self.plan.shares() if self.plan.weights else {}
        stages = self.stage_map()
        self.store.put(
            MEMBERSHIP_KEY,
            {
                "epoch": self.epoch,
                "n_micro": self.plan.n_micro,
                "hosts": {
                    str(h): {
                        "weight": float(w),
                        "share": int(shares.get(h, 0)),
                        "stage": stages.get(h),
                        "joined_epoch": self.joined_epoch.get(h, self.epoch),
                    }
                    for h, w in self.plan.weights.items()
                },
                "updated": self.clock(),
            },
        )

    def admit(self, host: int) -> bool:
        """Grow the plan onto ``host`` (in place, so every holder of the plan
        sees the new apportionment), bump the epoch, publish.  Returns False
        for a duplicate admit of a present member — idempotent, no epoch
        bump, so a raced double join request cannot double-apportion."""
        host = int(host)
        if host in self.plan.weights:
            return False
        grown = self.plan.retarget([*self.plan.weights, host])
        self.plan.weights.clear()
        self.plan.weights.update(grown.weights)
        self.epoch += 1
        self.joined_epoch[host] = self.epoch
        self.publish()
        return True

    def remove(self, host: int) -> None:
        """Record a departure *after* the plan has already shed the host
        (``MicrobatchPlan.evict`` via the response policy): bump the epoch and
        publish, which fences the host out of every future gather."""
        host = int(host)
        self.joined_epoch.pop(host, None)
        self.plan.weights.pop(host, None)
        self.epoch += 1
        self.publish()
        self.store.delete(f"beat/{host}")
        self.store.delete(f"join/{host}")

    # -- liveness ----------------------------------------------------------------
    def beat_ages(self, now: float | None = None) -> dict[int, float]:
        """{host: seconds since last heartbeat} for current members (a member
        that never beat counts from its admission publish)."""
        now = self.clock() if now is None else now
        ages: dict[int, float] = {}
        for host in self.hosts:
            beat = self.store.get(f"beat/{host}")
            if beat is None:
                record = self.store.get(MEMBERSHIP_KEY) or {}
                ages[host] = now - float(record.get("updated", now))
            else:
                ages[host] = now - float(beat.get("t", 0.0))
        return ages

    def expired(self, now: float | None = None) -> list[int]:
        return sorted(
            h
            for h, age in self.beat_ages(now).items()
            if age > self.liveness_timeout
        )

    def pending_joins(self) -> list[dict[str, Any]]:
        return list(self.store.scan("join").values())


class FleetController:
    """The membership transitions as a control-plane citizen (name: ``fleet``).

    Wires together the membership registry, the straggler response (whose
    plan/detector must grow and shrink in lockstep), the transport (fencing),
    the payback gate, and the checkpoint-before-evict barrier.  Every
    transition and every skipped transition is returned as a
    :class:`ControlAction`, so the ``ADAPT/fleet::*`` rows are the complete
    journal of fleet shape over the run.
    """

    name = "fleet"

    def __init__(
        self,
        membership: Membership,
        transport: FleetTransport,
        response: StragglerResponse,
        *,
        payback: PaybackPolicy | None = None,
        evict_barrier: Callable[[int, StragglerReport | None], ControlAction | None]
        | None = None,
        clock: Callable[[], float] = time.time,
    ) -> None:
        self.membership = membership
        self.transport = transport
        self.response = response
        self.payback = payback
        self.evict_barrier = evict_barrier
        self.clock = clock
        self.channels: tuple[str, ...] = ()
        self.joins_total = 0
        self.leaves_total = 0
        self.deferred_leaves = 0

    # -- Controller protocol ------------------------------------------------------
    def control(
        self, step: int, measurements: dict[str, Measurement]
    ) -> list[ControlAction]:
        actions: list[ControlAction] = []
        actions.extend(self._process_leaves(step))
        actions.extend(self._process_joins(step))
        return actions

    # -- leaves ------------------------------------------------------------------
    def _process_leaves(self, step: int) -> list[ControlAction]:
        membership = self.membership
        actions: list[ControlAction] = []
        for host in membership.expired():
            if len(membership.hosts) <= 1:
                break  # never fence out the last live host
            if self.evict_barrier is not None:
                barrier = self.evict_barrier(step, None)
                if barrier is None:
                    # save not durable yet: the leave retries next poll; the
                    # host keeps missing beats, so nothing is forgotten
                    self.deferred_leaves += 1
                    continue
                actions.append(barrier)
            self.response.remove_host(host)
            membership.remove(host)
            self.leaves_total += 1
            actions.append(
                ControlAction(
                    step=step,
                    controller=self.name,
                    trigger=f"DIST/host{host}::step",
                    action="leave",
                    detail={
                        "host": host,
                        "reason": "heartbeat_expired",
                        "epoch": membership.epoch,
                        "survivors": membership.hosts,
                    },
                )
            )
        return actions

    # -- joins -------------------------------------------------------------------
    def _mean_step_seconds(self) -> float:
        means = self.response.detector.host_means()
        return statistics.mean(means.values()) if means else 0.0

    def _process_joins(self, step: int) -> list[ControlAction]:
        membership = self.membership
        actions: list[ControlAction] = []
        for request in membership.pending_joins():
            try:
                host = int(request["host"])
            except (KeyError, TypeError, ValueError):
                continue
            if host in membership.plan.weights:
                # duplicate join of a present member: ack idempotently
                membership.store.delete(f"join/{host}")
                continue
            if self.payback is not None:
                gate = self.payback.join_gate(
                    step, host, len(membership.hosts), self._mean_step_seconds()
                )
                if gate is not None:
                    actions.append(gate)  # request stays pending; retried
                    continue
            membership.admit(host)
            self.response.register_host(host)
            membership.store.delete(f"join/{host}")
            self.joins_total += 1
            actions.append(
                ControlAction(
                    step=step,
                    controller=self.name,
                    trigger=f"join/{host}",
                    action="join",
                    detail={
                        "host": host,
                        "epoch": membership.epoch,
                        "weight": round(membership.plan.weights[host], 4),
                        "shares": membership.plan.shares(),
                    },
                )
            )
        return actions

    # -- external views -----------------------------------------------------------
    def status_payload(self) -> dict[str, Any]:
        """The ``/fleet`` endpoint + exporter payload."""
        membership = self.membership
        shares = membership.plan.shares() if membership.plan.weights else {}
        ages = membership.beat_ages()
        stages = membership.stage_map()
        return {
            "epoch": membership.epoch,
            "hosts": {
                str(h): {
                    "weight": float(membership.plan.weights[h]),
                    "share": int(shares.get(h, 0)),
                    "stage": stages.get(h),
                    "beat_age_s": round(ages.get(h, 0.0), 3),
                    "joined_epoch": membership.joined_epoch.get(h),
                }
                for h in membership.hosts
            },
            "joins_total": self.joins_total,
            "leaves_total": self.leaves_total,
            "reshard_defers_total": (
                sum(self.payback.defers.values()) if self.payback is not None else 0
            ),
            "deferred_leaves": self.deferred_leaves,
            "stale_samples_rejected": self.transport.stale_rejected,
            "liveness_timeout_s": membership.liveness_timeout,
        }
