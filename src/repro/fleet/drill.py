"""Nightly fleet drill: seeded process-fault matrix against real ranks.

``python -m repro.fleet.drill --seeds 3`` draws a deterministic
:class:`~repro.faults.plan.FaultPlan` over the rank-fault kinds
(``kill_rank`` / ``hang_rank`` / ``rejoin_rank`` / ``slow_rank``), maps it
onto the launcher's event script, runs a real multi-process fleet per seed,
and asserts the contract that makes elasticity trustworthy:

* every rank exits cleanly (``done``) or discovers its own eviction
  (``fenced``) — no rank ever wedges or crashes;
* the soak invariants hold on the scraped metrics pages: valid expositions,
  counters never regress, the membership epoch never regresses, every ADAPT
  action is wire-visible, cardinality stays bounded;
* the membership epoch accounts for every transition: it ends at exactly
  ``1 + joins + leaves + straggler evicts``;
* any fault drawn at all must leave at least one ``ADAPT/fleet::*`` or
  straggler row in the journal — a drill that injects faults and records no
  adaptation is a silent failure, not a pass.

Faults are clamped to the first half of the run so the steady-tail
cardinality invariant has a settled tail to check.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any

from ..faults.plan import RANK_FAULTS, FaultPlan
from ..monitor.promparse import parse_exposition
from ..soak.invariants import SnapshotRecord, check_snapshots
from .launch import FleetSettings, run_fleet

__all__ = ["drill_settings", "run_drill"]

#: statuses a rank may legitimately end a drill with
_OK_STATUSES = {"done", "fenced"}


def drill_settings(
    seed: int,
    *,
    hosts: int = 3,
    steps: int = 60,
    rate: float = 0.08,
) -> tuple[FleetSettings, FaultPlan]:
    """Draw the seeded fault plan and map it onto launcher events.

    ``rejoin_rank`` targets get a fresh host id (evicted ids never return —
    the detector enforces it); ``hang_rank`` schedules the matching SIGCONT a
    dozen polls later so the drill also exercises the stale-epoch fence: the
    resumed rank publishes with its pre-eviction epoch and must be rejected.
    """
    fault_window = max(steps // 2, 1)
    plan = FaultPlan.random(
        seed, fault_window, kinds=RANK_FAULTS, rate=rate, hosts=list(range(hosts))
    )
    settings = FleetSettings(
        hosts=hosts,
        steps=steps,
        liveness_timeout_s=0.8,
        poll_interval_s=0.1,
        seed=seed,
        snapshot_every=5,
    )
    next_id = hosts
    for event in plan:
        if event.kind == "kill_rank":
            settings.kill_at.append((event.step, event.target))
        elif event.kind == "hang_rank":
            settings.hang_at.append((event.step, event.target))
            settings.cont_at.append(
                (min(event.step + 12, steps - 1), event.target)
            )
        elif event.kind == "rejoin_rank":
            settings.join_at.append((event.step, next_id))
            next_id += 1
        elif event.kind == "slow_rank":
            settings.slow_at.append((event.step, event.target, event.arg or 3.0))
    return settings, plan


def _check_invariants(summary: dict[str, Any], n_faults: int) -> list[str]:
    failures: list[str] = []

    records = []
    for i, snapshot in enumerate(summary["snapshots"]):
        record = SnapshotRecord(
            index=i,
            step=snapshot["step"],
            source="render",
            actions=dict(snapshot["actions"]),
        )
        try:
            record.exposition = parse_exposition(snapshot["exposition"])
        except ValueError as exc:
            record.parse_error = str(exc)
        records.append(record)
    failures.extend(check_snapshots(records))

    for host, final in sorted(summary["finals"].items()):
        if final.get("status") not in _OK_STATUSES:
            failures.append(
                f"rank {host} ended {final.get('status')!r} "
                f"(steps={final.get('steps')})"
            )

    counts = summary["action_counts"]
    evicts = counts.get("stragglers::evict", 0)
    expected_epoch = 1 + summary["joins_total"] + summary["leaves_total"] + evicts
    if summary["epoch"] != expected_epoch:
        failures.append(
            f"membership epoch {summary['epoch']} != 1 + joins "
            f"{summary['joins_total']} + leaves {summary['leaves_total']} "
            f"+ evicts {evicts}"
        )

    adaptive = sum(
        count
        for key, count in counts.items()
        if key.startswith("fleet::") or key.startswith("stragglers::")
    )
    if n_faults > 0 and adaptive == 0:
        failures.append(
            f"{n_faults} deterministic faults injected but the journal "
            "records no fleet/straggler action"
        )
    return failures


def run_drill(
    seed: int, *, hosts: int = 3, steps: int = 60, rate: float = 0.08
) -> dict[str, Any]:
    """One seeded drill; returns the journal plus its invariant failures."""
    settings, plan = drill_settings(seed, hosts=hosts, steps=steps, rate=rate)
    summary = run_fleet(settings)
    summary["seed"] = seed
    summary["fault_plan"] = plan.describe()
    # a slow_rank may legitimately stay under the flag threshold (and with
    # two hosts it mathematically must: the median includes the slow host),
    # so only the deterministic transitions demand a journal row
    deterministic = sum(
        1 for e in plan if e.kind in ("kill_rank", "hang_rank", "rejoin_rank")
    )
    summary["failures"] = _check_invariants(summary, deterministic)
    return summary


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description="Seeded fleet fault drill")
    parser.add_argument("--seeds", type=int, default=3, help="seeds 0..N-1")
    parser.add_argument("--hosts", type=int, default=3)
    parser.add_argument("--steps", type=int, default=60)
    parser.add_argument("--rate", type=float, default=0.08)
    parser.add_argument("--json", action="store_true")
    args = parser.parse_args(argv)

    failed = 0
    for seed in range(args.seeds):
        result = run_drill(
            seed, hosts=args.hosts, steps=args.steps, rate=args.rate
        )
        status = "FAIL" if result["failures"] else "ok"
        failed += bool(result["failures"])
        if args.json:
            result.pop("snapshots", None)
            print(json.dumps(result, default=str))
        else:
            print(
                f"seed {seed}: {status} epoch={result['epoch']} "
                f"joins={result['joins_total']} leaves={result['leaves_total']} "
                f"defers={result['reshard_defers']} "
                f"stale_rejected={result['stale_rejected']} "
                f"faults=[{result['fault_plan'].replace(chr(10), '; ')}]"
            )
            for failure in result["failures"]:
                print(f"  FAIL: {failure}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
