"""File-backed rendezvous store — the fleet's sidecar KV + append-log substrate.

Every cross-process surface in :mod:`repro.fleet` (membership records,
heartbeats, join requests, step-time sample streams) is a key or an append-only
log in one shared directory, so a fleet needs nothing but a filesystem both
sides can see — no external services, no extra dependencies, and every byte of
coordination state is inspectable with ``cat`` after a failed drill.

Two primitives, two atomicity guarantees:

* **keys** (:meth:`FileStore.put` / :meth:`FileStore.get`) are single JSON
  documents written via the tmp-file + ``os.replace`` pattern the checkpoint
  layer established — a reader sees the old value or the new value, never a
  torn one;
* **logs** (:meth:`FileStore.append` / :meth:`FileStore.read_log`) are JSONL
  files opened with ``O_APPEND``; one record is one ``write()`` well under
  ``PIPE_BUF``, so concurrent appenders interleave at line granularity.
  Readers track a byte offset and only consume *complete* lines, so a reader
  racing an in-flight append simply picks the tail up next call.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

__all__ = ["FileStore"]

#: keys/log names are path-like but constrained — no traversal, no surprises
_KEY_RE = re.compile(r"^[A-Za-z0-9_.-]+(/[A-Za-z0-9_.-]+)*$")


class FileStore:
    """Atomic JSON keys + append-only JSONL logs under one root directory."""

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    # -- paths -----------------------------------------------------------------
    def _path(self, key: str, suffix: str) -> str:
        # the regex admits dots inside segments ("a.b"), so "." / ".."
        # segments need an explicit reject or a key could escape the root
        if not _KEY_RE.match(key) or any(
            seg in (".", "..") for seg in key.split("/")
        ):
            raise ValueError(f"invalid store key {key!r}")
        return os.path.join(self.root, *key.split("/")) + suffix

    # -- keys ------------------------------------------------------------------
    def put(self, key: str, value: dict[str, Any]) -> None:
        """Atomically replace ``key`` with ``value`` (tmp + ``os.replace``)."""
        path = self._path(key, ".json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(value, f)
        os.replace(tmp, path)

    def get(self, key: str, default: Any = None) -> Any:
        try:
            with open(self._path(key, ".json"), encoding="utf-8") as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            # a missing key and a key being replaced mid-read look the same
            # to a poller: absent now, present next poll
            return default

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key, ".json"))
        except FileNotFoundError:
            pass

    def scan(self, prefix: str) -> dict[str, Any]:
        """All keys under ``prefix/`` (one directory level), parsed."""
        directory = os.path.join(self.root, *prefix.split("/"))
        out: dict[str, Any] = {}
        try:
            names = sorted(os.listdir(directory))
        except FileNotFoundError:
            return out
        for name in names:
            if not name.endswith(".json"):
                continue
            key = f"{prefix}/{name[:-len('.json')]}"
            value = self.get(key)
            if value is not None:
                out[key] = value
        return out

    # -- logs ------------------------------------------------------------------
    def append(self, log: str, record: dict[str, Any]) -> None:
        """Append one JSONL record (single ``O_APPEND`` write: concurrent
        appenders interleave at line granularity, never mid-line)."""
        path = self._path(log, ".jsonl")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        line = (json.dumps(record) + "\n").encode("utf-8")
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, line)
        finally:
            os.close(fd)

    def read_log(self, log: str, offset: int = 0) -> tuple[list[dict[str, Any]], int]:
        """Complete records at/after byte ``offset`` + the next offset.

        Only lines terminated by ``\\n`` are consumed — a record mid-append
        stays in the file for the next read.  Undecodable complete lines are
        skipped (counted against no one: the store is a transport, policy on
        bad peers lives in the fencing layer above).
        """
        path = self._path(log, ".jsonl")
        try:
            with open(path, "rb") as f:
                f.seek(offset)
                data = f.read()
        except FileNotFoundError:
            return [], offset
        records: list[dict[str, Any]] = []
        consumed = 0
        for line in data.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break  # in-flight append: leave for the next read
            consumed += len(line)
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue
        return records, offset + consumed

    def logs(self, prefix: str) -> list[str]:
        """Log names under ``prefix/`` (one directory level)."""
        directory = os.path.join(self.root, *prefix.split("/"))
        try:
            names = sorted(os.listdir(directory))
        except FileNotFoundError:
            return []
        return [
            f"{prefix}/{n[:-len('.jsonl')]}" for n in names if n.endswith(".jsonl")
        ]
