"""Timers — caliper points around code regions (paper Sec. 2, Table 3).

A :class:`Timer` encapsulates one instance of **every registered clock**; querying
a timer returns the readings of all its clocks.  Timers live in a process-global
:class:`TimerDB` ("the internal timer database") addressed either by integer
handle — the Cactus C API style (``CCTK_TimerCreate`` → handle,
``CCTK_TimerStartI(handle)``) — or by name.  A thread-local running stack gives
hierarchical attribution (self time vs. child time) without explicit nesting
annotations.

Hot-path architecture (paper: "a high performance interface"):

* A timer does **not** hold a dict of clock objects on the fast path.  It holds
  two flat float arrays — accumulated totals and window marks — laid out by the
  process-wide :class:`~repro.core.clocks.ChannelLayout` for the current clock
  registry version.  ``start`` is one fused sampling pass into the marks array;
  ``stop`` is a second pass plus an element-wise diff into the accumulators.
* Clocks without a fused sampler (user :class:`~repro.core.clocks.CallbackClock`
  with arming hooks, exotic subclasses) keep the classic per-timer ``Clock``
  object path and are started/stopped around the fused pass.
* Clock instantiation is lazy: creating a timer allocates nothing clock-related;
  the layout is resolved on first start/read and re-resolved only when the
  registry version changes, so a clock registered mid-run appears on existing
  timers from their next window (the paper's extensibility guarantee).
* ``TimerDB.start/stop`` take a handle-indexed fast path — no name resolution
  and no database RLock for already-created timers; ``create`` and name lookups
  keep the locked slow path.
* ``Timer.clocks`` remains available as the compatibility view: fused clocks
  are exposed as array-backed proxy objects supporting the full Cactus clock
  API (``read/get/set/reset/start/stop``) over the timer's flat storage.

Flattened views namespace colliding channel names as ``<clock>.<channel>``
(two clocks exporting the same channel no longer silently overwrite each
other).
"""

from __future__ import annotations

import functools
import threading
from collections.abc import Callable, Iterator, Mapping
from contextlib import contextmanager

from . import clocks as _clocks
from .clocks import _REGISTRY_VERSION as _VERSION  # atomic int read; hot path

__all__ = ["Timer", "TimerDB", "timer_db", "timed", "reset_timer_db"]


class TimerError(RuntimeError):
    pass


class _FusedClockView:
    """Cactus clock API over one fused clock's slice of a timer's flat arrays.

    ``read``/``get``/``set``/``reset`` operate on the timer's accumulators for
    this clock's channels; ``start``/``stop`` open an independent accumulation
    window (marks local to the view) for code driving a single clock directly.

    Views resolve their channel indices against the timer's *current* layout
    on every use (cold path), so a view held across a mid-run clock
    registration keeps working; channels no longer present resolve to ``None``
    and read 0.0.  Layout sync itself only ever happens between windows.
    """

    __slots__ = ("name", "units", "_timer", "_channels", "_vmarks",
                 "_cached_layout", "_cached_indices")

    def __init__(self, timer: Timer, name: str, channels, units) -> None:
        self.name = name
        self.units = dict(units)
        self._timer = timer
        self._channels = tuple(channels)
        self._vmarks: dict[str, float] | None = None
        self._cached_layout: _clocks.ChannelLayout | None = None
        self._cached_indices: tuple = ()

    # -- helpers (timer lock held) --------------------------------------------
    def _indices_locked(self) -> tuple:
        layout = self._timer._layout
        if layout is not self._cached_layout:
            get = layout.key_index.get
            self._cached_indices = tuple(get((self.name, ch)) for ch in self._channels)
            self._cached_layout = layout
        return self._cached_indices

    def _current_locked(self) -> list[float]:
        """Channel values incl. live timer window; timer lock held."""
        timer = self._timer
        accum = timer._accum
        indices = self._indices_locked()
        vals = [accum[i] if i is not None else 0.0 for i in indices]
        live = timer._layout.sample() if (timer.running or self._vmarks) else None
        if timer.running:
            marks = timer._marks
            vals = [
                v + live[i] - marks[i] if i is not None else v
                for v, i in zip(vals, indices)
            ]
        if self._vmarks is not None:
            vmarks = self._vmarks
            vals = [
                v + live[i] - vmarks[ch] if i is not None and ch in vmarks else v
                for v, i, ch in zip(vals, indices, self._channels)
            ]
        return vals

    # -- Cactus clock API ----------------------------------------------------
    def read(self) -> _clocks.ClockValues:
        with self._timer._lock:
            if not self._timer.running:
                self._timer._sync_layout_locked()
            vals = self._current_locked()
        return _clocks.ClockValues(
            values=dict(zip(self._channels, vals)), units=dict(self.units)
        )

    def get(self) -> dict[str, float]:
        return self.read().values

    def set(self, values: Mapping[str, float]) -> None:
        timer = self._timer
        with timer._lock:
            if not timer.running:
                timer._sync_layout_locked()
            indices = self._indices_locked()
            accum = timer._accum
            for i, ch in zip(indices, self._channels):
                if i is not None:
                    accum[i] = float(values.get(ch, 0.0))
            if timer.running or self._vmarks is not None:
                live = timer._layout.sample()
                if timer.running:
                    for i in indices:
                        if i is not None:
                            timer._marks[i] = live[i]
                if self._vmarks is not None:
                    self._vmarks = {
                        ch: live[i]
                        for ch, i in zip(self._channels, indices)
                        if i is not None
                    }

    def reset(self) -> None:
        self.set({})

    def start(self) -> None:
        timer = self._timer
        with timer._lock:
            if self._vmarks is not None:
                return
            if not timer.running:  # never re-layout under an open window
                timer._sync_layout_locked()
            live = timer._layout.sample()
            self._vmarks = {
                ch: live[i]
                for ch, i in zip(self._channels, self._indices_locked())
                if i is not None
            }

    def stop(self) -> None:
        timer = self._timer
        with timer._lock:
            if self._vmarks is None:
                return
            live = timer._layout.sample()
            accum = timer._accum
            vmarks = self._vmarks
            for ch, i in zip(self._channels, self._indices_locked()):
                if i is not None and ch in vmarks:
                    accum[i] += live[i] - vmarks[ch]
            self._vmarks = None

    def destroy(self) -> None:
        with self._timer._lock:
            self._vmarks = None

    @property
    def is_running(self) -> bool:
        return self._vmarks is not None


class Timer:
    """A named caliper point.  Not usually constructed directly — use
    :meth:`TimerDB.create` so the timer is registered in the database."""

    __slots__ = (
        "name",
        "handle",
        "count",
        "running",
        "parent_name",
        "_lock",
        "_layout",
        "_accum",
        "_marks",
        "_nonfused",
        "_views",
    )

    def __init__(self, name: str, handle: int) -> None:
        self.name = name
        self.handle = handle
        self.count = 0  # number of completed start/stop windows
        self.running = False
        self.parent_name: str | None = None
        self._lock = threading.Lock()
        # lazy: resolved on first start/read, re-resolved on registry bumps
        self._layout: _clocks.ChannelLayout | None = None
        self._accum: list[float] = []
        self._marks: list[float] = []
        self._nonfused: dict[str, _clocks.Clock] = {}
        self._views: dict[str, object] | None = None

    # -- layout management (lock held) ----------------------------------------
    def _sync_layout_locked(self) -> None:
        """Adopt the current registry layout, carrying accumulated values over
        by (clock, channel) key.  Must not be called mid-window."""
        layout = self._layout
        if layout is not None and layout.version == _VERSION[0]:
            return
        new = _clocks.channel_layout()
        if new is layout:
            return
        accum = [0.0] * new.n_fused
        if layout is not None:
            old_accum = self._accum
            get = new.key_index.get
            for i, key in enumerate(layout.fused_keys):
                j = get(key)
                if j is not None:
                    accum[j] = old_accum[i]
        nonfused: dict[str, _clocks.Clock] = {}
        for name in new.nonfused_names:
            clock = self._nonfused.get(name)
            nonfused[name] = clock if clock is not None else _clocks.make_clock(name)
        self._layout = new
        self._accum = accum
        self._nonfused = nonfused
        self._views = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        with self._lock:
            if self.running:
                raise TimerError(f"timer {self.name!r} already running")
            layout = self._layout
            if layout is None or layout.version != _VERSION[0]:
                self._sync_layout_locked()
                layout = self._layout
            # sample before flipping state: a sampler exception must not leave
            # the timer stuck "running" with stale marks
            marks = layout.sample()
            if self._nonfused:
                started = []
                try:
                    for clock in self._nonfused.values():
                        clock.start()
                        started.append(clock)
                except BaseException:
                    # unwind: a failed arming hook must not leave earlier
                    # clocks mid-window (their next start would no-op)
                    for clock in started:
                        clock.stop()
                    raise
            self._marks = marks
            self.running = True

    def stop(self) -> None:
        with self._lock:
            if not self.running:
                raise TimerError(f"timer {self.name!r} is not running")
            # stop non-fused clocks first: their on_stop hooks can raise, and
            # a retried stop() must not re-apply the fused diff (Clock.stop
            # no-ops when already stopped, so the retry is safe either way)
            if self._nonfused:
                for clock in self._nonfused.values():
                    clock.stop()
            now = self._layout.sample()
            marks = self._marks
            self._accum = [
                a + v - m for a, v, m in zip(self._accum, now, marks)
            ]
            self.running = False
            self.count += 1

    def reset(self) -> None:
        with self._lock:
            if self._layout is not None:
                self._accum = [0.0] * self._layout.n_fused
                if self.running:
                    self._marks = self._layout.sample()
            for clock in self._nonfused.values():
                clock.reset()
            self.count = 0

    # -- queries ---------------------------------------------------------------
    def _values_locked(self) -> list[float]:
        vals = list(self._accum)
        if self.running:
            now = self._layout.sample()
            marks = self._marks
            vals = [a + n - m for a, n, m in zip(vals, now, marks)]
        return vals

    def read(self) -> dict[str, _clocks.ClockValues]:
        """Readings for all clocks (running timers report up-to-now values)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            vals = self._values_locked()
            out: dict[str, _clocks.ClockValues] = {}
            for name, sl, channels, units in layout.clock_meta:
                out[name] = _clocks.ClockValues(
                    values=dict(zip(channels, vals[sl])), units=dict(units)
                )
            for name, clock in self._nonfused.items():
                out[name] = clock.read()
        return out

    def read_flat(self) -> dict[str, float]:
        """Flattened {channel: value} view across all clocks.

        Channel names colliding across clocks come back namespaced as
        ``<clock>.<channel>`` (every colliding export is renamed, so no clock's
        reading silently overwrites another's).
        """
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            flat = dict(zip(layout.fused_flat, self._values_locked()))
            for name, clock in self._nonfused.items():
                mapping = layout.nonfused_flat.get(name, {})
                for ch, v in clock.read().values.items():
                    flat[mapping.get(ch, ch)] = v
        return flat

    def seconds(self) -> float:
        """Accumulated wall seconds (the most common query)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            idx = layout.walltime_index
            if idx is None:
                clock = self._nonfused.get("walltime")
                return clock.read().scalar() if clock is not None else 0.0
            if not self.running:
                return self._accum[idx]
            now = self._layout.sample()
            return self._accum[idx] + now[idx] - self._marks[idx]

    def channel(self, name: str) -> float:
        """One flat channel's current value (0.0 when absent) — the cheap
        single-metric read used by cross-process reducers."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            idx = self._layout.flat_index.get(name)
            if idx is not None:
                if not self.running:
                    return self._accum[idx]
                now = self._layout.sample()
                return self._accum[idx] + now[idx] - self._marks[idx]
        return self.read_flat().get(name, 0.0)

    def set_channel(self, name: str, value: float) -> None:
        """Directly set one flat channel's accumulated value (Cactus
        ``CCTK_TimerSet`` analogue; used by reducers publishing remote
        measurements into the database)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            idx = self._layout.flat_index.get(name)
            if idx is None:
                # plain name that got collision-namespaced: the canonical
                # export is the clock named like its channel (e.g. walltime),
                # mirroring the read-side fallback in seconds()
                idx = self._layout.key_index.get((name, name))
            if idx is None:
                for clock_name, clock in self._nonfused.items():
                    mapping = self._layout.nonfused_flat.get(clock_name, {})
                    for ch, flat in mapping.items():
                        if flat == name:
                            values = dict(clock.read().values)
                            values[ch] = float(value)
                            clock.set(values)
                            return
                raise TimerError(
                    f"timer {self.name!r} has no channel {name!r}"
                )
            self._accum[idx] = float(value)
            if self.running:
                now = self._layout.sample()
                self._marks[idx] = now[idx]

    @property
    def clocks(self) -> dict[str, object]:
        """Compatibility view: {clock name: clock object}.  Fused clocks are
        array-backed proxies over this timer's flat storage; slow-path clocks
        are the real per-timer ``Clock`` instances."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            if self._views is None:
                layout = self._layout
                views: dict[str, object] = {}
                for name, _sl, channels, units in layout.clock_meta:
                    views[name] = _FusedClockView(self, name, channels, units)
                views.update(self._nonfused)
                self._views = views
            return self._views


class TimerDB:
    """The queryable timer database.  Any routine can obtain timing statistics
    for any other routine by querying this database (paper Sec. 2).

    ``start``/``stop`` by integer handle bypass the database lock entirely:
    the timer list is append-only, so an index read is safe under the GIL.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._timers: list[Timer] = []
        self._by_name: dict[str, int] = {}
        self._tls = threading.local()

    # -- creation / lookup -----------------------------------------------------
    def create(self, name: str, exist_ok: bool = True) -> int:
        """Create (or look up) a timer; returns its integer handle."""
        with self._lock:
            if name in self._by_name:
                if not exist_ok:
                    raise TimerError(f"timer {name!r} already exists")
                return self._by_name[name]
            handle = len(self._timers)
            timer = Timer(name, handle)
            self._timers.append(timer)
            self._by_name[name] = handle
            return handle

    def get(self, ref: int | str) -> Timer:
        with self._lock:
            if isinstance(ref, str):
                if ref not in self._by_name:
                    raise TimerError(f"no timer named {ref!r}")
                ref = self._by_name[ref]
            if not 0 <= ref < len(self._timers):
                raise TimerError(f"invalid timer handle {ref}")
            return self._timers[ref]

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._by_name

    def names(self) -> list[str]:
        with self._lock:
            return [t.name for t in self._timers]

    def timers(self) -> list[Timer]:
        with self._lock:
            return list(self._timers)

    # -- running stack (hierarchy) ----------------------------------------------
    def _stack(self) -> list[str]:
        try:
            return self._tls.stack
        except AttributeError:
            stack: list[str] = []
            self._tls.stack = stack
            return stack

    def start(self, ref: int | str) -> None:
        timers = self._timers
        if type(ref) is int and 0 <= ref < len(timers):
            timer = timers[ref]  # fast path: append-only list, no lock
        else:
            timer = self.get(ref)
        try:
            stack = self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
        timer.parent_name = stack[-1] if stack else None
        timer.start()
        stack.append(timer.name)

    def stop(self, ref: int | str) -> None:
        timers = self._timers
        if type(ref) is int and 0 <= ref < len(timers):
            timer = timers[ref]
        else:
            timer = self.get(ref)
        timer.stop()
        try:
            stack = self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
        if stack:
            if stack[-1] == timer.name:  # common LIFO case
                stack.pop()
                return
            # Tolerate out-of-order stops (paper allows overlapping measurement
            # windows); remove the most recent occurrence.
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == timer.name:
                    del stack[i]
                    break

    def reset(self, ref: int | str) -> None:
        self.get(ref).reset()

    def reset_all(self) -> None:
        for timer in self.timers():
            timer.reset()

    def read(self, ref: int | str) -> dict[str, _clocks.ClockValues]:
        return self.get(ref).read()

    # -- queries -------------------------------------------------------------
    def snapshot(self) -> dict[str, dict[str, float]]:
        """{timer name: flattened channel readings + count} for all timers."""
        out: dict[str, dict[str, float]] = {}
        for timer in self.timers():
            flat = timer.read_flat()
            flat["count"] = float(timer.count)
            out[timer.name] = flat
        return out

    def total_seconds(self, prefix: str = "") -> float:
        return sum(
            t.seconds() for t in self.timers() if t.name.startswith(prefix)
        )

    # -- sugar -----------------------------------------------------------------
    @contextmanager
    def timing(self, name: str) -> Iterator[Timer]:
        # dict reads are atomic and names are never deleted, so the common
        # already-created case skips the database lock entirely
        handle = self._by_name.get(name)
        if handle is None:
            handle = self.create(name)
        self.start(handle)
        try:
            yield self._timers[handle]
        finally:
            self.stop(handle)


_DB = TimerDB()


def timer_db() -> TimerDB:
    """The process-global timer database."""
    return _DB


def reset_timer_db() -> TimerDB:
    """Replace the global DB (tests)."""
    global _DB
    _DB = TimerDB()
    return _DB


def timed(name: str | None = None) -> Callable:
    """Decorator placing caliper points around a function."""

    def deco(fn: Callable) -> Callable:
        label = name or f"func/{fn.__qualname__}"

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with _DB.timing(label):
                return fn(*args, **kwargs)

        return wrapper

    return deco
