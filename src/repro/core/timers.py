"""Timers — caliper points around code regions (paper Sec. 2, Table 3).

A :class:`Timer` encapsulates one instance of **every registered clock**; querying
a timer returns the readings of all its clocks.  Timers live in a process-global
:class:`TimerDB` ("the internal timer database") addressed either by integer
handle — the Cactus C API style (``CCTK_TimerCreate`` → handle,
``CCTK_TimerStartI(handle)``) — or by name.  A thread-local running stack gives
hierarchical attribution (self time vs. child time) without explicit nesting
annotations.

Overhead notes (paper: "a high performance interface"): creating a timer
allocates (do not create in inner loops); start/stop costs the underlying clock
samples plus one list push/pop — benchmarked in
``benchmarks/bench_clock_overhead.py``.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, List, Optional

from . import clocks as _clocks

__all__ = ["Timer", "TimerDB", "timer_db", "timed", "reset_timer_db"]


class TimerError(RuntimeError):
    pass


class Timer:
    """A named caliper point.  Not usually constructed directly — use
    :meth:`TimerDB.create` so the timer is registered in the database."""

    __slots__ = (
        "name",
        "handle",
        "clocks",
        "count",
        "running",
        "_clock_version",
        "parent_name",
        "_lock",
    )

    def __init__(self, name: str, handle: int) -> None:
        self.name = name
        self.handle = handle
        self.clocks: Dict[str, _clocks.Clock] = _clocks.make_all_clocks()
        self._clock_version = _clocks.registry_version()
        self.count = 0  # number of completed start/stop windows
        self.running = False
        self.parent_name: Optional[str] = None
        self._lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    def _refresh_clocks(self) -> None:
        """Pick up newly registered clocks (extensibility: a clock registered
        mid-run appears on existing timers from their next window)."""
        if self._clock_version == _clocks.registry_version():
            return
        existing = set(self.clocks)
        for name in _clocks.clock_names():
            if name not in existing:
                self.clocks[name] = _clocks.make_clock(name)
        for name in list(self.clocks):
            if name not in _clocks.clock_names():
                del self.clocks[name]
        self._clock_version = _clocks.registry_version()

    def start(self) -> None:
        with self._lock:
            if self.running:
                raise TimerError(f"timer {self.name!r} already running")
            self._refresh_clocks()
            for clock in self.clocks.values():
                clock.start()
            self.running = True

    def stop(self) -> None:
        with self._lock:
            if not self.running:
                raise TimerError(f"timer {self.name!r} is not running")
            for clock in self.clocks.values():
                clock.stop()
            self.running = False
            self.count += 1

    def reset(self) -> None:
        with self._lock:
            for clock in self.clocks.values():
                clock.reset()
            self.count = 0

    def read(self) -> Dict[str, _clocks.ClockValues]:
        """Readings for all clocks (running timers report up-to-now values)."""
        with self._lock:
            return {name: clock.read() for name, clock in self.clocks.items()}

    def read_flat(self) -> Dict[str, float]:
        """Flattened {channel: value} view across all clocks."""
        flat: Dict[str, float] = {}
        for values in self.read().values():
            flat.update(values.values)
        return flat

    def seconds(self) -> float:
        """Accumulated wall seconds (the most common query)."""
        clock = self.clocks.get("walltime")
        return clock.read().scalar() if clock is not None else 0.0


class TimerDB:
    """The queryable timer database.  Any routine can obtain timing statistics
    for any other routine by querying this database (paper Sec. 2)."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._timers: List[Timer] = []
        self._by_name: Dict[str, int] = {}
        self._tls = threading.local()

    # -- creation / lookup -----------------------------------------------------
    def create(self, name: str, exist_ok: bool = True) -> int:
        """Create (or look up) a timer; returns its integer handle."""
        with self._lock:
            if name in self._by_name:
                if not exist_ok:
                    raise TimerError(f"timer {name!r} already exists")
                return self._by_name[name]
            handle = len(self._timers)
            timer = Timer(name, handle)
            self._timers.append(timer)
            self._by_name[name] = handle
            return handle

    def get(self, ref: "int | str") -> Timer:
        with self._lock:
            if isinstance(ref, str):
                if ref not in self._by_name:
                    raise TimerError(f"no timer named {ref!r}")
                ref = self._by_name[ref]
            if not 0 <= ref < len(self._timers):
                raise TimerError(f"invalid timer handle {ref}")
            return self._timers[ref]

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._by_name

    def names(self) -> List[str]:
        with self._lock:
            return [t.name for t in self._timers]

    def timers(self) -> List[Timer]:
        with self._lock:
            return list(self._timers)

    # -- running stack (hierarchy) ----------------------------------------------
    def _stack(self) -> List[str]:
        if not hasattr(self._tls, "stack"):
            self._tls.stack = []
        return self._tls.stack

    def start(self, ref: "int | str") -> None:
        timer = self.get(ref)
        stack = self._stack()
        timer.parent_name = stack[-1] if stack else None
        timer.start()
        stack.append(timer.name)

    def stop(self, ref: "int | str") -> None:
        timer = self.get(ref)
        timer.stop()
        stack = self._stack()
        # Tolerate out-of-order stops (paper allows overlapping measurement
        # windows); remove the most recent occurrence.
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == timer.name:
                del stack[i]
                break

    def reset(self, ref: "int | str") -> None:
        self.get(ref).reset()

    def reset_all(self) -> None:
        for timer in self.timers():
            timer.reset()

    def read(self, ref: "int | str") -> Dict[str, _clocks.ClockValues]:
        return self.get(ref).read()

    # -- queries -------------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """{timer name: flattened channel readings + count} for all timers."""
        out: Dict[str, Dict[str, float]] = {}
        for timer in self.timers():
            flat = timer.read_flat()
            flat["count"] = float(timer.count)
            out[timer.name] = flat
        return out

    def total_seconds(self, prefix: str = "") -> float:
        return sum(
            t.seconds() for t in self.timers() if t.name.startswith(prefix)
        )

    # -- sugar -----------------------------------------------------------------
    @contextmanager
    def timing(self, name: str) -> Iterator[Timer]:
        handle = self.create(name)
        self.start(handle)
        try:
            yield self.get(handle)
        finally:
            self.stop(handle)


_DB = TimerDB()


def timer_db() -> TimerDB:
    """The process-global timer database."""
    return _DB


def reset_timer_db() -> TimerDB:
    """Replace the global DB (tests)."""
    global _DB
    _DB = TimerDB()
    return _DB


def timed(name: Optional[str] = None) -> Callable:
    """Decorator placing caliper points around a function."""

    def deco(fn: Callable) -> Callable:
        label = name or f"func/{fn.__qualname__}"

        def wrapper(*args, **kwargs):
            with _DB.timing(label):
                return fn(*args, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
