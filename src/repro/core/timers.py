"""Timers — caliper points around code regions (paper Sec. 2, Table 3).

A :class:`Timer` encapsulates one instance of **every registered clock**; querying
a timer returns the readings of all its clocks.  Timers live in a process-global
:class:`TimerDB` ("the internal timer database") addressed either by integer
handle — the Cactus C API style (``CCTK_TimerCreate`` → handle,
``CCTK_TimerStartI(handle)``) — or by name.  A thread-local running stack gives
hierarchical attribution (self time vs. child time) without explicit nesting
annotations.

Hot-path architecture (paper: "a high performance interface"):

* A timer does **not** hold a dict of clock objects on the fast path.  It holds
  two flat float arrays — accumulated totals and window marks — laid out by the
  process-wide :class:`~repro.core.clocks.ChannelLayout` for the current clock
  registry version.  ``start`` is one fused sampling pass into the marks array;
  ``stop`` is a second pass plus an element-wise diff into the accumulators.
* Clocks without a fused sampler (user :class:`~repro.core.clocks.CallbackClock`
  with arming hooks, exotic subclasses) keep the classic per-timer ``Clock``
  object path and are started/stopped around the fused pass.
* Clock instantiation is lazy: creating a timer allocates nothing clock-related;
  the layout is resolved on first start/read and re-resolved only when the
  registry version changes, so a clock registered mid-run appears on existing
  timers from their next window (the paper's extensibility guarantee).
* ``TimerDB.start/stop`` take a handle-indexed fast path — no name resolution
  and no database RLock for already-created timers; ``create`` and name lookups
  keep the locked slow path.
* ``Timer.clocks`` remains available as the compatibility view: fused clocks
  are exposed as array-backed proxy objects supporting the full Cactus clock
  API (``read/get/set/reset/start/stop``) over the timer's flat storage.

Flattened views namespace colliding channel names as ``<clock>.<channel>``
(two clocks exporting the same channel no longer silently overwrite each
other).

The supported call-path-facing surface lives one layer up in
:mod:`repro.timing`: hierarchical scopes (:meth:`TimerDB.scope` /
:meth:`TimerDB.scope_handle`) derive path-addressed timers from the running
stack, and :meth:`TimerDB.tree` aggregates the recorded per-parent attribution
into an inclusive/exclusive forest.  (The PR-4 flat sugar — ``TimerDB.timing``
and a module-level ``timed`` — finished its deprecation window and was
removed; use :func:`repro.timing.scope` / :func:`repro.timing.timed`.)
"""

from __future__ import annotations

import threading
from collections.abc import Iterator, Mapping
from contextlib import contextmanager
from dataclasses import dataclass, field

from . import clocks as _clocks
from .clocks import _REGISTRY_VERSION as _VERSION  # atomic int read; hot path

__all__ = [
    "PARENT_STATS_CAP",
    "ScopeHandle",
    "Timer",
    "TimerDB",
    "TimerNode",
    "path_matches",
    "reset_timer_db",
    "timer_db",
]

#: Per-timer bound on distinct parent-chain attribution buckets.  A timer
#: entered under ever-changing enclosing scopes (an unbounded set of call
#: paths — usually a scope name interpolated with a request or step id) would
#: otherwise grow ``_parent_stats`` without limit over a long run.  At the cap
#: the least-recently-windowed chain is evicted (its seconds stay in the
#: timer's accumulators; only the per-chain split is dropped) and the timer's
#: ``parent_stats_evictions`` counter is bumped — exported as
#: ``repro_timing_parent_stats_evictions_total`` so a soak can alert on it.
PARENT_STATS_CAP = 128


def path_matches(name: str, prefix: str) -> bool:
    """Whole-path-segment prefix match over ``/``-separated timer paths.

    ``"serve"`` matches ``"serve"`` and ``"serve/admit"`` but *not*
    ``"server_x"`` (the classic ``startswith`` false positive).  A trailing
    ``/`` on the prefix restricts the match to strict descendants.
    """
    if not prefix:
        return True
    if name == prefix:
        return True
    sep = prefix if prefix.endswith("/") else prefix + "/"
    return name.startswith(sep)


class TimerError(RuntimeError):
    pass


class _FusedClockView:
    """Cactus clock API over one fused clock's slice of a timer's flat arrays.

    ``read``/``get``/``set``/``reset`` operate on the timer's accumulators for
    this clock's channels; ``start``/``stop`` open an independent accumulation
    window (marks local to the view) for code driving a single clock directly.

    Views resolve their channel indices against the timer's *current* layout
    on every use (cold path), so a view held across a mid-run clock
    registration keeps working; channels no longer present resolve to ``None``
    and read 0.0.  Layout sync itself only ever happens between windows.
    """

    __slots__ = ("name", "units", "_timer", "_channels", "_vmarks",
                 "_cached_layout", "_cached_indices")

    def __init__(self, timer: Timer, name: str, channels, units) -> None:
        self.name = name
        self.units = dict(units)
        self._timer = timer
        self._channels = tuple(channels)
        self._vmarks: dict[str, float] | None = None
        self._cached_layout: _clocks.ChannelLayout | None = None
        self._cached_indices: tuple = ()

    # -- helpers (timer lock held) --------------------------------------------
    def _indices_locked(self) -> tuple:
        layout = self._timer._layout
        if layout is not self._cached_layout:
            get = layout.key_index.get
            self._cached_indices = tuple(get((self.name, ch)) for ch in self._channels)
            self._cached_layout = layout
        return self._cached_indices

    def _current_locked(self) -> list[float]:
        """Channel values incl. live timer window; timer lock held."""
        timer = self._timer
        accum = timer._accum
        indices = self._indices_locked()
        vals = [accum[i] if i is not None else 0.0 for i in indices]
        live = timer._layout.sample() if (timer.running or self._vmarks) else None
        if timer.running:
            marks = timer._marks
            vals = [
                v + live[i] - marks[i] if i is not None else v
                for v, i in zip(vals, indices)
            ]
        if self._vmarks is not None:
            vmarks = self._vmarks
            vals = [
                v + live[i] - vmarks[ch] if i is not None and ch in vmarks else v
                for v, i, ch in zip(vals, indices, self._channels)
            ]
        return vals

    # -- Cactus clock API ----------------------------------------------------
    def read(self) -> _clocks.ClockValues:
        with self._timer._lock:
            if not self._timer.running:
                self._timer._sync_layout_locked()
            vals = self._current_locked()
        return _clocks.ClockValues(
            values=dict(zip(self._channels, vals)), units=dict(self.units)
        )

    def get(self) -> dict[str, float]:
        return self.read().values

    def set(self, values: Mapping[str, float]) -> None:
        timer = self._timer
        with timer._lock:
            if not timer.running:
                timer._sync_layout_locked()
            indices = self._indices_locked()
            accum = timer._accum
            for i, ch in zip(indices, self._channels):
                if i is not None:
                    accum[i] = float(values.get(ch, 0.0))
            if timer.running or self._vmarks is not None:
                live = timer._layout.sample()
                if timer.running:
                    for i in indices:
                        if i is not None:
                            timer._marks[i] = live[i]
                if self._vmarks is not None:
                    self._vmarks = {
                        ch: live[i]
                        for ch, i in zip(self._channels, indices)
                        if i is not None
                    }

    def reset(self) -> None:
        self.set({})

    def start(self) -> None:
        timer = self._timer
        with timer._lock:
            if self._vmarks is not None:
                return
            if not timer.running:  # never re-layout under an open window
                timer._sync_layout_locked()
            live = timer._layout.sample()
            self._vmarks = {
                ch: live[i]
                for ch, i in zip(self._channels, self._indices_locked())
                if i is not None
            }

    def stop(self) -> None:
        timer = self._timer
        with timer._lock:
            if self._vmarks is None:
                return
            live = timer._layout.sample()
            accum = timer._accum
            vmarks = self._vmarks
            for ch, i in zip(self._channels, self._indices_locked()):
                if i is not None and ch in vmarks:
                    accum[i] += live[i] - vmarks[ch]
            self._vmarks = None

    def destroy(self) -> None:
        with self._timer._lock:
            self._vmarks = None

    @property
    def is_running(self) -> bool:
        return self._vmarks is not None


class Timer:
    """A named caliper point.  Not usually constructed directly — use
    :meth:`TimerDB.create` so the timer is registered in the database."""

    __slots__ = (
        "name",
        "handle",
        "count",
        "running",
        "parent_name",
        "_lock",
        "_layout",
        "_accum",
        "_marks",
        "_nonfused",
        "_views",
        "_parent_path",
        "_parent_stats",
        "parent_stats_evictions",
    )

    def __init__(self, name: str, handle: int) -> None:
        self.name = name
        self.handle = handle
        self.count = 0  # number of completed start/stop windows
        self.running = False
        self.parent_name: str | None = None
        self._lock = threading.Lock()
        # lazy: resolved on first start/read, re-resolved on registry bumps
        self._layout: _clocks.ChannelLayout | None = None
        self._accum: list[float] = []
        self._marks: list[float] = []
        self._nonfused: dict[str, _clocks.Clock] = {}
        self._views: dict[str, object] | None = None
        # per-call-path window attribution: {ancestor path tuple: [wall_s,
        # count, last-window tick]} — a timer entered under several enclosing
        # scopes (a shared library routine, the final checkpoint in SHUTDOWN)
        # splits exactly in tree(), including its own sub-scopes.  Bounded to
        # PARENT_STATS_CAP chains per timer (LRU by last-window tick).
        self._parent_path: tuple[str, ...] = ()
        self._parent_stats: dict[tuple[str, ...], list] = {}
        self.parent_stats_evictions = 0

    # -- layout management (lock held) ----------------------------------------
    def _sync_layout_locked(self) -> None:
        """Adopt the current registry layout, carrying accumulated values over
        by (clock, channel) key.  Must not be called mid-window."""
        layout = self._layout
        if layout is not None and layout.version == _VERSION[0]:
            return
        new = _clocks.channel_layout()
        if new is layout:
            return
        accum = [0.0] * new.n_fused
        if layout is not None:
            old_accum = self._accum
            get = new.key_index.get
            for i, key in enumerate(layout.fused_keys):
                j = get(key)
                if j is not None:
                    accum[j] = old_accum[i]
        nonfused: dict[str, _clocks.Clock] = {}
        for name in new.nonfused_names:
            clock = self._nonfused.get(name)
            nonfused[name] = clock if clock is not None else _clocks.make_clock(name)
        self._layout = new
        self._accum = accum
        self._nonfused = nonfused
        self._views = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        with self._lock:
            if self.running:
                raise TimerError(f"timer {self.name!r} already running")
            layout = self._layout
            if layout is None or layout.version != _VERSION[0]:
                self._sync_layout_locked()
                layout = self._layout
            # sample before flipping state: a sampler exception must not leave
            # the timer stuck "running" with stale marks
            marks = layout.sample()
            if self._nonfused:
                started = []
                try:
                    for clock in self._nonfused.values():
                        clock.start()
                        started.append(clock)
                except BaseException:
                    # unwind: a failed arming hook must not leave earlier
                    # clocks mid-window (their next start would no-op)
                    for clock in started:
                        clock.stop()
                    raise
            self._marks = marks
            self.running = True

    def stop(self) -> None:
        with self._lock:
            if not self.running:
                raise TimerError(f"timer {self.name!r} is not running")
            # stop non-fused clocks first: their on_stop hooks can raise, and
            # a retried stop() must not re-apply the fused diff (Clock.stop
            # no-ops when already stopped, so the retry is safe either way)
            if self._nonfused:
                for clock in self._nonfused.values():
                    clock.stop()
            now = self._layout.sample()
            marks = self._marks
            self._accum = [
                a + v - m for a, v, m in zip(self._accum, now, marks)
            ]
            self.running = False
            self.count += 1
            # per-call-path attribution (one dict update per window): the
            # wall seconds of this window land in the bucket of the full
            # enclosing-scope chain recorded at start
            wi = self._layout.walltime_index
            stats = self._parent_stats
            entry = stats.get(self._parent_path)
            if entry is None:
                if len(stats) >= PARENT_STATS_CAP:
                    # evict the least-recently-windowed chain (O(cap), paid
                    # only on an at-cap insert); the evicted seconds remain in
                    # the timer's accumulators — only the per-chain split goes
                    oldest = min(stats, key=lambda p: stats[p][2])
                    del stats[oldest]
                    self.parent_stats_evictions += 1
                stats[self._parent_path] = [
                    now[wi] - marks[wi] if wi is not None else 0.0, 1, self.count
                ]
            else:
                if wi is not None:
                    entry[0] += now[wi] - marks[wi]
                entry[1] += 1
                entry[2] = self.count

    def reset(self) -> None:
        with self._lock:
            if self._layout is not None:
                self._accum = [0.0] * self._layout.n_fused
                if self.running:
                    self._marks = self._layout.sample()
            for clock in self._nonfused.values():
                clock.reset()
            self.count = 0
            self._parent_stats = {}
            self.parent_stats_evictions = 0

    # -- queries ---------------------------------------------------------------
    def _values_locked(self) -> list[float]:
        vals = list(self._accum)
        if self.running:
            now = self._layout.sample()
            marks = self._marks
            vals = [a + n - m for a, n, m in zip(vals, now, marks)]
        return vals

    def read(self) -> dict[str, _clocks.ClockValues]:
        """Readings for all clocks (running timers report up-to-now values)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            vals = self._values_locked()
            out: dict[str, _clocks.ClockValues] = {}
            for name, sl, channels, units in layout.clock_meta:
                out[name] = _clocks.ClockValues(
                    values=dict(zip(channels, vals[sl])), units=dict(units)
                )
            for name, clock in self._nonfused.items():
                out[name] = clock.read()
        return out

    def read_flat(self) -> dict[str, float]:
        """Flattened {channel: value} view across all clocks.

        Channel names colliding across clocks come back namespaced as
        ``<clock>.<channel>`` (every colliding export is renamed, so no clock's
        reading silently overwrites another's).
        """
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            flat = dict(zip(layout.fused_flat, self._values_locked()))
            for name, clock in self._nonfused.items():
                mapping = layout.nonfused_flat.get(name, {})
                for ch, v in clock.read().values.items():
                    flat[mapping.get(ch, ch)] = v
        return flat

    def seconds(self) -> float:
        """Accumulated wall seconds (the most common query)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            layout = self._layout
            idx = layout.walltime_index
            if idx is None:
                clock = self._nonfused.get("walltime")
                return clock.read().scalar() if clock is not None else 0.0
            if not self.running:
                return self._accum[idx]
            now = self._layout.sample()
            return self._accum[idx] + now[idx] - self._marks[idx]

    def channel(self, name: str) -> float:
        """One flat channel's current value (0.0 when absent) — the cheap
        single-metric read used by cross-process reducers."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            idx = self._layout.flat_index.get(name)
            if idx is not None:
                if not self.running:
                    return self._accum[idx]
                now = self._layout.sample()
                return self._accum[idx] + now[idx] - self._marks[idx]
        return self.read_flat().get(name, 0.0)

    def set_channel(self, name: str, value: float) -> None:
        """Directly set one flat channel's accumulated value (Cactus
        ``CCTK_TimerSet`` analogue; used by reducers publishing remote
        measurements into the database)."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            idx = self._layout.flat_index.get(name)
            if idx is None:
                # plain name that got collision-namespaced: the canonical
                # export is the clock named like its channel (e.g. walltime),
                # mirroring the read-side fallback in seconds()
                idx = self._layout.key_index.get((name, name))
            if idx is None:
                for clock_name, clock in self._nonfused.items():
                    mapping = self._layout.nonfused_flat.get(clock_name, {})
                    for ch, flat in mapping.items():
                        if flat == name:
                            values = dict(clock.read().values)
                            values[ch] = float(value)
                            clock.set(values)
                            return
                raise TimerError(
                    f"timer {self.name!r} has no channel {name!r}"
                )
            self._accum[idx] = float(value)
            if self.running:
                now = self._layout.sample()
                self._marks[idx] = now[idx]

    def parent_stats(self, live: bool = False) -> dict[tuple[str, ...], tuple[float, int]]:
        """Window attribution per enclosing call path:
        ``{ancestor scope chain (() for top level): (wall seconds, windows)}``.

        ``live=True`` folds a currently open window's elapsed wall seconds
        into its chain's bucket (window count unchanged) — what tree views on
        a live monitor need so a still-running ancestor keeps its subtree.
        """
        with self._lock:
            out = {p: (e[0], e[1]) for p, e in self._parent_stats.items()}
            if live and self.running:
                wi = self._layout.walltime_index
                if wi is not None:
                    delta = self._layout.sample()[wi] - self._marks[wi]
                    s, c = out.get(self._parent_path, (0.0, 0))
                    out[self._parent_path] = (s + delta, c)
        return out

    @property
    def clocks(self) -> dict[str, object]:
        """Compatibility view: {clock name: clock object}.  Fused clocks are
        array-backed proxies over this timer's flat storage; slow-path clocks
        are the real per-timer ``Clock`` instances."""
        with self._lock:
            if not self.running:
                self._sync_layout_locked()
            if self._views is None:
                layout = self._layout
                views: dict[str, object] = {}
                for name, _sl, channels, units in layout.clock_meta:
                    views[name] = _FusedClockView(self, name, channels, units)
                views.update(self._nonfused)
                self._views = views
            return self._views


class ScopeHandle:
    """A pre-resolved hierarchical scope — the hot-path form of the scope API.

    Holds the :class:`Timer` for one absolute path, resolved **once** at
    construction (``timing.scope_handle("train/step")``); entering/exiting the
    handle is the PR-2 fused start/stop window plus the thread-local stack
    push/pop — no dict lookups, no name resolution, no database lock.  Parent
    attribution is still dynamic: every enter re-derives ``parent_name`` from
    the current thread's running stack, so a handle entered under different
    enclosing scopes reports under whichever parent was active.

    Handles are cached per database by :meth:`TimerDB.scope_handle`.  Like
    the underlying timer, a handle admits one open window at a time: a second
    enter — same thread or another — raises ``TimerError`` without touching
    the running window's attribution.  Threads timing the same region
    concurrently should use per-thread paths (cf. the concurrency tests).
    """

    __slots__ = ("path", "timer", "_tls")

    def __init__(self, db: TimerDB, path: str) -> None:
        self.path = path
        self.timer = db.get(db.create(path))
        self._tls = db._tls

    def __enter__(self) -> Timer:
        timer = self.timer
        tls = self._tls
        try:
            stack = tls.stack
        except AttributeError:
            stack = tls.stack = []
        # start first: a failed start (double enter) must not corrupt the
        # open window's recorded attribution
        timer.start()
        timer.parent_name = stack[-1] if stack else None
        timer._parent_path = tuple(stack)
        stack.append(timer.name)
        return timer

    def __exit__(self, exc_type, exc, tb) -> None:
        self.timer.stop()
        stack = self._tls.stack
        if stack:
            name = self.timer.name
            if stack[-1] == name:  # common LIFO case
                stack.pop()
            else:  # overlapping windows: drop the most recent occurrence
                for i in range(len(stack) - 1, -1, -1):
                    if stack[i] == name:
                        del stack[i]
                        break

    def seconds(self) -> float:
        return self.timer.seconds()

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return f"ScopeHandle({self.path!r})"


@dataclass
class TimerNode:
    """One node of the parent/child timer forest built by :meth:`TimerDB.tree`.

    ``inclusive`` is the timer's accumulated wall seconds; ``exclusive`` is
    self time — inclusive minus the sum of the children's inclusive seconds
    (unclamped, so the arithmetic identity is exact; real nestings keep it
    non-negative because child windows sit inside parent windows on one
    monotonic clock).
    """

    name: str
    count: int
    inclusive: float
    exclusive: float
    children: list[TimerNode] = field(default_factory=list)

    @property
    def depth(self) -> int:
        """Height of this subtree (a leaf has depth 1)."""
        return 1 + max((c.depth for c in self.children), default=0)

    def walk(self) -> Iterator[tuple[int, TimerNode]]:
        """Depth-first ``(level, node)`` traversal of this subtree."""
        todo: list[tuple[int, TimerNode]] = [(0, self)]
        while todo:
            level, node = todo.pop()
            yield level, node
            todo.extend((level + 1, c) for c in reversed(node.children))


class TimerDB:
    """The queryable timer database.  Any routine can obtain timing statistics
    for any other routine by querying this database (paper Sec. 2).

    ``start``/``stop`` by integer handle bypass the database lock entirely:
    the timer list is append-only, so an index read is safe under the GIL.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._timers: list[Timer] = []
        self._by_name: dict[str, int] = {}
        self._tls = threading.local()
        self._scope_handles: dict[str, ScopeHandle] = {}

    # -- creation / lookup -----------------------------------------------------
    def create(self, name: str, exist_ok: bool = True) -> int:
        """Create (or look up) a timer; returns its integer handle."""
        with self._lock:
            if name in self._by_name:
                if not exist_ok:
                    raise TimerError(f"timer {name!r} already exists")
                return self._by_name[name]
            handle = len(self._timers)
            timer = Timer(name, handle)
            self._timers.append(timer)
            self._by_name[name] = handle
            return handle

    def get(self, ref: int | str) -> Timer:
        with self._lock:
            if isinstance(ref, str):
                if ref not in self._by_name:
                    raise TimerError(f"no timer named {ref!r}")
                ref = self._by_name[ref]
            if not 0 <= ref < len(self._timers):
                raise TimerError(f"invalid timer handle {ref}")
            return self._timers[ref]

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._by_name

    def names(self) -> list[str]:
        with self._lock:
            return [t.name for t in self._timers]

    def timers(self) -> list[Timer]:
        with self._lock:
            return list(self._timers)

    # -- running stack (hierarchy) ----------------------------------------------
    def _stack(self) -> list[str]:
        try:
            return self._tls.stack
        except AttributeError:
            stack: list[str] = []
            self._tls.stack = stack
            return stack

    def start(self, ref: int | str) -> None:
        timers = self._timers
        if type(ref) is int and 0 <= ref < len(timers):
            timer = timers[ref]  # fast path: append-only list, no lock
        else:
            timer = self.get(ref)
        try:
            stack = self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
        timer.start()  # before attribution: a double start must not corrupt it
        timer.parent_name = stack[-1] if stack else None
        timer._parent_path = tuple(stack)
        stack.append(timer.name)

    def stop(self, ref: int | str) -> None:
        timers = self._timers
        if type(ref) is int and 0 <= ref < len(timers):
            timer = timers[ref]
        else:
            timer = self.get(ref)
        timer.stop()
        try:
            stack = self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
        if stack:
            if stack[-1] == timer.name:  # common LIFO case
                stack.pop()
                return
            # Tolerate out-of-order stops (paper allows overlapping measurement
            # windows); remove the most recent occurrence.
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == timer.name:
                    del stack[i]
                    break

    def reset(self, ref: int | str) -> None:
        self.get(ref).reset()

    def reset_all(self) -> None:
        for timer in self.timers():
            timer.reset()

    def read(self, ref: int | str) -> dict[str, _clocks.ClockValues]:
        return self.get(ref).read()

    # -- queries -------------------------------------------------------------
    def snapshot(self) -> dict[str, dict[str, float]]:
        """{timer name: flattened channel readings + count} for all timers."""
        out: dict[str, dict[str, float]] = {}
        for timer in self.timers():
            flat = timer.read_flat()
            flat["count"] = float(timer.count)
            out[timer.name] = flat
        return out

    def total_seconds(self, prefix: str = "") -> float:
        """Summed wall seconds over timers whose path equals ``prefix`` or
        lives under it (whole-segment match: ``"serve"`` does not pick up a
        ``server_x`` timer).  Note that summing a parent scope together with
        its children counts nested time more than once — for self-vs-children
        breakdowns use :meth:`tree`."""
        return sum(
            t.seconds() for t in self.timers() if path_matches(t.name, prefix)
        )

    # -- hierarchy --------------------------------------------------------------
    def scope_handle(self, path: str) -> ScopeHandle:
        """The cached :class:`ScopeHandle` for an absolute timer path.

        Resolution (name → timer object) happens here, once; the returned
        handle's enter/exit is the lock-free fused fast path.  This is the
        primary API for hot loops::

            h = db.scope_handle("train/step")
            ...
            with h:          # zero dict lookups
                step()
        """
        handle = self._scope_handles.get(path)
        if handle is None:
            with self._lock:
                handle = self._scope_handles.get(path)
                if handle is None:
                    handle = ScopeHandle(self, path)
                    self._scope_handles[path] = handle
        return handle

    @contextmanager
    def scope(self, name: str) -> Iterator[Timer]:
        """Open a hierarchical scope: the timer's path is ``name`` nested
        under the enclosing scope on this thread's running stack, so

            with db.scope("step"):
                with db.scope("forward"): ...

        records timers ``step`` and ``step/forward`` with parent/child
        attribution derived from runtime nesting (no annotations).  ``name``
        may itself contain ``/`` segments.  Pre-resolve hot paths with
        :meth:`scope_handle` instead (absolute path, no per-entry joining).
        """
        try:
            stack = self._tls.stack
        except AttributeError:
            stack = self._tls.stack = []
        path = f"{stack[-1]}/{name}" if stack else name
        handle = self._by_name.get(path)
        if handle is None:
            handle = self.create(path)
        timer = self._timers[handle]
        timer.start()  # before attribution: a double start must not corrupt it
        timer.parent_name = stack[-1] if stack else None
        timer._parent_path = tuple(stack)
        stack.append(path)
        try:
            yield timer
        finally:
            timer.stop()
            if stack:
                if stack[-1] == path:
                    stack.pop()
                else:
                    for i in range(len(stack) - 1, -1, -1):
                        if stack[i] == path:
                            del stack[i]
                            break

    def current_scope(self) -> str:
        """This thread's innermost running scope path (``""`` outside any)."""
        try:
            stack = self._tls.stack
        except AttributeError:
            return ""
        return stack[-1] if stack else ""

    def tree(self) -> list[TimerNode]:
        """The parent/child forest over all timers, from recorded call-path
        attribution (SPACE-Timers-style tree view).

        Every completed (or live) window was recorded under the full chain of
        enclosing scopes active at its start, so the forest is an exact call
        tree: a timer entered under *several* enclosing chains (a shared
        helper, the final checkpoint write in SHUTDOWN) splits into one node
        per chain, each carrying exactly the wall seconds and window count
        accrued there — including its own sub-scopes, which land under the
        matching split.  For properly nested windows this guarantees
        ``sum(child.inclusive) <= parent.inclusive`` on every node
        (overlapping/out-of-order windows, which the paper permits, are
        attributed best-effort by the stack state at start).  A node whose
        recorded chain has no corresponding parent node (root-level timers,
        hand-set attribution, never-started rows) roots its own tree.
        ``exclusive`` is inclusive minus the direct children's inclusive.
        """
        timers = self.timers()
        nodes: dict[tuple[str, ...], TimerNode] = {}  # full chain -> node
        singles: list[tuple[Timer, tuple[str, ...] | None]] = []
        for t in timers:
            buckets = t.parent_stats(live=True)
            if len(buckets) <= 1:
                # single- or never-windowed timer (incl. set_channel-published
                # rows): one node whose inclusive is the live seconds()
                # reading, so set()/reset() adjustments stay authoritative
                singles.append((t, next(iter(buckets), None)))
                continue
            for chain, (seconds, count) in buckets.items():
                nodes[chain + (t.name,)] = TimerNode(
                    name=t.name, count=count, inclusive=seconds, exclusive=0.0
                )
        for t, chain in singles:
            if chain is None:
                chain = (t.parent_name,) if t.parent_name else ()
            key = chain + (t.name,)
            if key not in nodes:  # split timers keep their exact buckets
                nodes[key] = TimerNode(
                    name=t.name, count=t.count, inclusive=t.seconds(), exclusive=0.0
                )
        roots: list[TimerNode] = []
        for key, node in nodes.items():
            parent = nodes.get(key[:-1]) if len(key) > 1 else None
            if parent is None or parent is node:
                roots.append(node)
            else:
                parent.children.append(node)
        for node in nodes.values():
            node.exclusive = node.inclusive - sum(c.inclusive for c in node.children)
        return roots

    # -- boundedness introspection ------------------------------------------------
    def cardinality(self) -> dict[str, int]:
        """Size of every internal store that must stay bounded on a long run:
        ``{"timers", "scope_handles", "parent_stats_buckets",
        "parent_stats_buckets_max", "parent_stats_evictions"}``.

        This is the hook the metrics exporter and the soak gate read — a
        control loop (or user code) that allocates a new timer or attribution
        bucket per step shows up here as monotonic growth long before it OOMs.
        Counter-store cardinality lives in
        :func:`repro.core.clocks.counter_stats`.
        """
        timers = self.timers()
        buckets = [len(t._parent_stats) for t in timers]
        return {
            "timers": len(timers),
            "scope_handles": len(self._scope_handles),
            "parent_stats_buckets": sum(buckets),
            "parent_stats_buckets_max": max(buckets, default=0),
            "parent_stats_evictions": sum(
                t.parent_stats_evictions for t in timers
            ),
        }


_DB = TimerDB()


def timer_db() -> TimerDB:
    """The process-global timer database (the active
    :class:`repro.timing.TimingSession`'s database while one is entered)."""
    return _DB


def reset_timer_db() -> TimerDB:
    """Replace the global DB (tests).  Prefer ``with repro.timing.session():``
    for new code — it scopes the swap and restores the previous database."""
    global _DB
    _DB = TimerDB()
    return _DB


def _install_db(db: TimerDB) -> TimerDB:
    """Swap the process-global database, returning the previous one.

    Internal wiring for :class:`repro.timing.TimingSession`; everything that
    defaults to :func:`timer_db` (scopes, reports, detectors, monitors) picks
    up the session database for the session's lifetime.
    """
    global _DB
    prev, _DB = _DB, db
    return prev
