"""The paper's primary contribution: an extensible timing infrastructure
(clocks + timers + scheduler-integrated caliper points) and profiling-driven
adaptation (AdaptCheck).  See DESIGN.md §2-3 for the Cactus → JAX mapping."""

from .adaptive import (
    AdaptiveCheckpointController,
    AdaptiveCheckpointPolicy,
    CheckpointDurationPredictor,
    Decision,
)
from .clocks import (
    CallbackClock,
    Clock,
    ClockValues,
    CounterClock,
    clock_names,
    counter_cell,
    counter_channel,
    counter_values,
    fold_pending_counters,
    increment_counter,
    make_all_clocks,
    make_clock,
    register_clock,
    reset_default_clocks,
    unregister_clock,
)
from .params import Param, ParamRegistry, param_registry, reset_param_registry
from .report import (
    TimerLogger,
    adapt_rows,
    bin_distribution,
    format_adapt_report,
    format_report,
    report_rows,
    straggler_rows,
)
from .schedule import BINS, RunState, ScheduledRoutine, Scheduler
from .timers import Timer, TimerDB, reset_timer_db, timed, timer_db


__all__ = [
    "CallbackClock",
    "Clock",
    "ClockValues",
    "CounterClock",
    "clock_names",
    "counter_cell",
    "counter_channel",
    "counter_values",
    "fold_pending_counters",
    "increment_counter",
    "make_all_clocks",
    "make_clock",
    "register_clock",
    "reset_default_clocks",
    "unregister_clock",
    "Timer",
    "TimerDB",
    "reset_timer_db",
    "timed",
    "timer_db",
    "BINS",
    "RunState",
    "ScheduledRoutine",
    "Scheduler",
    "AdaptiveCheckpointController",
    "AdaptiveCheckpointPolicy",
    "CheckpointDurationPredictor",
    "Decision",
    "TimerLogger",
    "adapt_rows",
    "bin_distribution",
    "format_adapt_report",
    "format_report",
    "report_rows",
    "straggler_rows",
    "Param",
    "ParamRegistry",
    "param_registry",
    "reset_param_registry",
]
