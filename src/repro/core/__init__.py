"""The paper's primary contribution: an extensible timing infrastructure
(clocks + timers + scheduler-integrated caliper points) and profiling-driven
adaptation (AdaptCheck).  See DESIGN.md §2-3 for the Cactus → JAX mapping."""

from .clocks import (
    CallbackClock,
    Clock,
    ClockValues,
    CounterClock,
    clock_names,
    counter_cell,
    counter_channel,
    counter_values,
    increment_counter,
    make_all_clocks,
    make_clock,
    register_clock,
    reset_default_clocks,
    unregister_clock,
)
from .timers import Timer, TimerDB, reset_timer_db, timed, timer_db
from .schedule import BINS, RunState, ScheduledRoutine, Scheduler
from .adaptive import (
    AdaptiveCheckpointController,
    AdaptiveCheckpointPolicy,
    CheckpointDurationPredictor,
    Decision,
)
from .report import TimerLogger, bin_distribution, format_report, report_rows, straggler_rows
from .params import Param, ParamRegistry, param_registry, reset_param_registry

__all__ = [
    "CallbackClock",
    "Clock",
    "ClockValues",
    "CounterClock",
    "clock_names",
    "counter_cell",
    "counter_channel",
    "counter_values",
    "increment_counter",
    "make_all_clocks",
    "make_clock",
    "register_clock",
    "reset_default_clocks",
    "unregister_clock",
    "Timer",
    "TimerDB",
    "reset_timer_db",
    "timed",
    "timer_db",
    "BINS",
    "RunState",
    "ScheduledRoutine",
    "Scheduler",
    "AdaptiveCheckpointController",
    "AdaptiveCheckpointPolicy",
    "CheckpointDurationPredictor",
    "Decision",
    "TimerLogger",
    "bin_distribution",
    "format_report",
    "report_rows",
    "straggler_rows",
    "Param",
    "ParamRegistry",
    "param_registry",
    "reset_param_registry",
]
