"""AdaptCheck — adaptive checkpoint control from real-time profiling (paper Sec. 3.2).

The controller consumes the timing infrastructure's measurements (total wall time
and accumulated checkpoint time, read from the timer database) and decides, each
iteration, whether a checkpoint should be written now.  Guarantees, matching the
paper:

* **Weak fraction bound** — no checkpoint is *started* while the fraction of wall
  time already spent checkpointing exceeds ``max_fraction``.  (Weak: a checkpoint
  that pushes the fraction above the bound afterwards is allowed.)
* **Max-interval guarantee** — if more than ``max_interval_seconds`` of wall time
  have passed since the last checkpoint, a checkpoint is forced regardless of the
  fraction bound (fault-tolerance floor).  This overrides the fraction bound, as
  in the paper.

Beyond-paper (the paper's stated future work, implemented here):

* **Duration predictor** — a least-squares ``duration ≈ a + b·bytes`` model over
  the observed checkpoint history (falling back to an EMA when bytes do not
  vary).  With the predictor on, the controller checkpoints *as early as the
  bound allows* — i.e. when ``(ckpt + t̂)/(total + t̂) ≤ max_fraction`` — which
  keeps the realised fraction close to the bound from below instead of
  oscillating around it.
* **Queue-deadline final checkpoint** — given ``queue_ends_at`` (seconds of
  wall time available to the job), the controller forces a final checkpoint when
  the predicted write time (+ safety margin) would no longer fit before the
  queue expires, making the final checkpoint reliable.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "CheckpointDurationPredictor",
    "AdaptiveCheckpointPolicy",
    "AdaptiveCheckpointController",
    "Decision",
]


class CheckpointDurationPredictor:
    """Predicts the next checkpoint's duration from (bytes, duration) history."""

    def __init__(self, window: int = 16, default_seconds: float = 1.0) -> None:
        self.window = int(window)
        self.default_seconds = float(default_seconds)
        self._history: list[tuple[float, float]] = []  # (bytes, seconds)

    def observe(self, seconds: float, nbytes: float = 0.0) -> None:
        if seconds < 0 or not math.isfinite(seconds):
            return
        self._history.append((float(max(nbytes, 0.0)), float(seconds)))
        if len(self._history) > self.window:
            self._history.pop(0)

    @property
    def n_observations(self) -> int:
        return len(self._history)

    def predict(self, nbytes: float | None = None) -> float:
        """Predicted duration for a checkpoint of ``nbytes`` (or 'like recent')."""
        if not self._history:
            return self.default_seconds
        xs = [b for b, _ in self._history]
        ys = [s for _, s in self._history]
        n = len(xs)
        if nbytes is not None and n >= 2 and (max(xs) - min(xs)) > 1e-9:
            # least squares fit duration = a + b * bytes
            mx = sum(xs) / n
            my = sum(ys) / n
            sxx = sum((x - mx) ** 2 for x in xs)
            sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
            b = sxy / sxx
            a = my - b * mx
            pred = a + b * float(nbytes)
            if math.isfinite(pred) and pred > 0:
                return pred
        # EMA fallback (recent-weighted)
        ema = ys[0]
        for y in ys[1:]:
            ema = 0.5 * ema + 0.5 * y
        return max(ema, 0.0)


@dataclass(frozen=True)
class AdaptiveCheckpointPolicy:
    """Steerable policy parameters (see core/params.py for runtime steering)."""

    mode: str = "adaptive"  # "fixed" | "adaptive"
    #: fixed mode: checkpoint every N iterations (the paper's baseline: 512).
    every_iterations: int = 512
    #: adaptive mode: weak upper bound on ckpt_time / total_time.
    max_fraction: float = 0.05
    #: adaptive mode: force a checkpoint after this much wall time without one.
    max_interval_seconds: float = float("inf")
    #: never checkpoint more often than this (thrash guard).
    min_interval_seconds: float = 0.0
    #: use the duration predictor to stay close to the bound from below.
    use_predictor: bool = True
    #: wall-time budget for the whole run (queue allocation); None = unlimited.
    queue_seconds: float | None = None
    #: safety margin multiplier applied to the predicted final-ckpt duration.
    deadline_safety: float = 2.0

    def validate(self) -> None:
        if self.mode not in ("fixed", "adaptive"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if not (0.0 < self.max_fraction <= 1.0):
            raise ValueError("max_fraction must be in (0, 1]")
        if self.every_iterations < 1:
            raise ValueError("every_iterations must be >= 1")
        if self.max_interval_seconds <= 0:
            raise ValueError("max_interval_seconds must be positive")
        if self.min_interval_seconds < 0:
            raise ValueError("min_interval_seconds must be >= 0")


@dataclass(frozen=True)
class Decision:
    checkpoint: bool
    reason: str
    fraction: float
    predicted_seconds: float

    def __bool__(self) -> bool:  # pragma: no cover - sugar
        return self.checkpoint


class AdaptiveCheckpointController:
    """Decides when to checkpoint, from profiling measurements.

    The controller is deliberately *pure with respect to time*: callers pass in
    ``now`` (wall clock), ``total_seconds`` and ``checkpoint_seconds`` (usually
    read from the timer DB), so it is trivially testable and replayable.
    """

    def __init__(self, policy: AdaptiveCheckpointPolicy) -> None:
        policy.validate()
        self.policy = policy
        self.predictor = CheckpointDurationPredictor()
        self._last_checkpoint_at: float | None = None
        self._started_at: float | None = None
        self._final_done = False
        self.n_checkpoints = 0
        self.n_suppressed = 0
        self.decisions: list[Decision] = []

    # -- lifecycle ------------------------------------------------------------
    def start_run(self, now: float) -> None:
        self._started_at = now
        if self._last_checkpoint_at is None:
            self._last_checkpoint_at = now

    @property
    def started_at(self) -> float:
        return self._started_at if self._started_at is not None else 0.0

    def observe_checkpoint(self, now: float, seconds: float, nbytes: float = 0.0) -> None:
        """Record a completed checkpoint (feeds the predictor and interval)."""
        self.predictor.observe(seconds, nbytes)
        self._last_checkpoint_at = now
        self.n_checkpoints += 1

    # -- the decision ------------------------------------------------------------
    def decide(
        self,
        *,
        iteration: int,
        now: float,
        total_seconds: float,
        checkpoint_seconds: float,
        next_checkpoint_bytes: float | None = None,
    ) -> Decision:
        p = self.policy
        predicted = self.predictor.predict(next_checkpoint_bytes)
        fraction = checkpoint_seconds / total_seconds if total_seconds > 0 else 0.0

        decision = self._decide_inner(
            iteration=iteration,
            now=now,
            total_seconds=total_seconds,
            checkpoint_seconds=checkpoint_seconds,
            fraction=fraction,
            predicted=predicted,
        )
        if not decision.checkpoint:
            self.n_suppressed += 1
        self.decisions.append(decision)
        return decision

    def _decide_inner(
        self,
        *,
        iteration: int,
        now: float,
        total_seconds: float,
        checkpoint_seconds: float,
        fraction: float,
        predicted: float,
    ) -> Decision:
        p = self.policy

        if p.mode == "fixed":
            do = iteration > 0 and iteration % p.every_iterations == 0
            return Decision(do, "fixed-interval" if do else "fixed-interval-skip", fraction, predicted)

        since_last = (
            now - self._last_checkpoint_at if self._last_checkpoint_at is not None else float("inf")
        )

        # (0) queue deadline: force the reliable final checkpoint.
        if p.queue_seconds is not None and self._started_at is not None and not self._final_done:
            remaining = (self._started_at + p.queue_seconds) - now
            if remaining <= p.deadline_safety * predicted:
                self._final_done = True
                return Decision(True, "queue-deadline-final", fraction, predicted)

        # (1) fault-tolerance floor: overrides the fraction bound.
        if since_last >= p.max_interval_seconds:
            return Decision(True, "max-interval", fraction, predicted)

        # (2) thrash guard.
        if since_last < p.min_interval_seconds:
            return Decision(False, "min-interval", fraction, predicted)

        # (3) weak upper bound (paper): never start while above the bound.
        if fraction > p.max_fraction:
            return Decision(False, "fraction-bound", fraction, predicted)

        # (4) predictor-aware admission (beyond-paper): checkpoint as early as
        # the bound allows, so the realised fraction tracks the bound from below.
        if p.use_predictor and self.predictor.n_observations > 0:
            lookahead = (checkpoint_seconds + predicted) / max(total_seconds + predicted, 1e-12)
            if lookahead <= p.max_fraction:
                return Decision(True, "predictor-admit", fraction, predicted)
            return Decision(False, "predictor-defer", fraction, predicted)

        # No history yet: admit (we are under the bound).
        return Decision(True, "under-bound", fraction, predicted)

    # -- reporting -----------------------------------------------------------
    def summary(self) -> dict:
        return {
            "mode": self.policy.mode,
            "n_checkpoints": self.n_checkpoints,
            "n_suppressed": self.n_suppressed,
            "max_fraction": self.policy.max_fraction,
            "max_interval_seconds": self.policy.max_interval_seconds,
            "predictor_observations": self.predictor.n_observations,
            "predicted_next_seconds": self.predictor.predict(),
        }
