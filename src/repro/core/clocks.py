"""Clocks — the low-level measurement entities of the timing infrastructure.

Faithful to the paper (Sec. 2, Tables 1-2): a *clock* is an object created from a
set of callbacks (``create/destroy/start/stop/read/reset/get/set``) that measures
"any kind of event" — wall time, CPU time, cycle counters, or discrete events such
as I/O bytes or FLOPs executed.  Clocks are registered with the infrastructure via
a standard registration mechanism so new metrics require *no modification to any
existing timing code*: every :class:`~repro.core.timers.Timer` automatically
encapsulates one instance of every registered clock.

Hardware adaptation (see DESIGN.md): TPUs expose no user-readable PMU, so the
PAPI-analogue clocks here are *derived* device clocks (``xla_flops``/``xla_bytes``)
fed by XLA's compiled cost analysis, plus generic :class:`CounterClock` channels
for framework events (checkpoint bytes, collective bytes, tokens processed).

Performance architecture (paper: "a high performance interface"):

* **Fused sampling.**  Built-in clocks implement :meth:`Clock.fused_sampler`,
  returning a closure that reads the clock's raw channel values as a flat
  sequence of floats.  :func:`channel_layout` composes every fused sampler of
  the current registry into one :class:`ChannelLayout` whose ``sample()`` fills
  a flat float array in a single pass — a timer start/stop window is two such
  passes plus an element-wise diff, with no per-clock dicts or locks.  The
  layout is stamped with the registry version and cached process-wide, so all
  timers share one resolved layout per registry generation.
* **Slow-path compatibility.**  Clocks without a fused sampler (e.g. a user
  :class:`CallbackClock` with ``on_start``/``on_stop`` arming hooks) keep the
  classic per-timer ``Clock`` object path.  New clocks must either implement
  fused sampling or accept the slow-path cost.
* **Lock-free counters.**  :func:`increment_counter` appends to a per-channel
  pending list (``list.append`` is an atomic C operation under the GIL), and
  readers fold pending amounts into a base total under a read-side lock.
  Hot loops should resolve a channel once with :func:`counter_cell` and call
  the returned cell directly — that is a single C-level call per increment.
"""

from __future__ import annotations

import os
import threading
import time
from collections.abc import Callable, Mapping, Sequence
from dataclasses import dataclass


__all__ = [
    "Clock",
    "ClockValues",
    "CallbackClock",
    "WalltimeClock",
    "CPUTimeClock",
    "PerfCounterClock",
    "ThreadCPUClock",
    "RSSClock",
    "CounterClock",
    "ChannelLayout",
    "channel_layout",
    "register_clock",
    "unregister_clock",
    "clock_names",
    "registry_version",
    "make_clock",
    "make_all_clocks",
    "counter_cell",
    "counter_channel",
    "counter_names",
    "counter_stats",
    "counter_values",
    "increment_counter",
    "fold_pending_counters",
    "reset_default_clocks",
]


@dataclass
class ClockValues:
    """A multi-valued clock reading (a clock can measure several values at once,
    e.g. multiple PAPI counters)."""

    values: dict[str, float]
    units: dict[str, str]

    def __getitem__(self, key: str) -> float:
        return self.values[key]

    def scalar(self) -> float:
        """The clock's primary value (first channel)."""
        return next(iter(self.values.values())) if self.values else 0.0


class Clock:
    """Base clock.  Subclasses implement ``_now() -> dict`` returning the current
    raw counter values; accumulation across start/stop windows is handled here so
    that a clock can be started and stopped many times, with ``read`` returning
    the accumulated measure (Cactus semantics: reset sets accumulation to zero).
    """

    #: registry name; subclasses override.
    name: str = "abstract"
    #: units per channel.
    units: Mapping[str, str] = {}

    def __init__(self) -> None:
        self._running = False
        self._accum: dict[str, float] = {}
        self._mark: dict[str, float] = {}

    # -- core sampling hook -------------------------------------------------
    def _now(self) -> dict[str, float]:  # pragma: no cover - abstract
        raise NotImplementedError

    def fused_sampler(self) -> Callable[[], Sequence[float]] | None:
        """Zero-arg closure returning this clock's raw channel values (ordered
        as ``units``) as a flat float sequence, for the fused timer hot path.

        Return ``None`` (the default) for clocks that need per-window object
        state or arming hooks; such clocks take the per-timer slow path.
        """
        return None

    # -- Cactus clock API ----------------------------------------------------
    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._mark = self._now()

    def stop(self) -> None:
        if not self._running:
            return
        now = self._now()
        for key, value in now.items():
            self._accum[key] = self._accum.get(key, 0.0) + (value - self._mark.get(key, 0.0))
        self._running = False

    def reset(self) -> None:
        self._accum = {}
        if self._running:
            self._mark = self._now()

    def read(self) -> ClockValues:
        values = dict(self._accum)
        if self._running:
            now = self._now()
            for key, value in now.items():
                values[key] = values.get(key, 0.0) + (value - self._mark.get(key, 0.0))
        for key in self._channels():
            values.setdefault(key, 0.0)
        return ClockValues(values=values, units=dict(self.units))

    # Cactus `get`/`set`: direct access to the accumulator.
    def get(self) -> dict[str, float]:
        return self.read().values

    def set(self, values: Mapping[str, float]) -> None:
        self._accum = dict(values)
        if self._running:
            self._mark = self._now()

    def destroy(self) -> None:
        self._running = False
        self._accum = {}

    @property
    def is_running(self) -> bool:
        return self._running

    def _channels(self) -> Sequence[str]:
        return tuple(self.units.keys())


class CallbackClock(Clock):
    """A clock built from user callbacks — the paper's extension mechanism.

    ``sample`` returns the raw counter values; optional ``on_start``/``on_stop``
    callbacks allow clocks that must arm hardware counters.  Callback clocks
    keep the classic per-timer object path (no fused sampler) so their arming
    hooks fire once per window, exactly as before.
    """

    def __init__(
        self,
        name: str,
        sample: Callable[[], Mapping[str, float]],
        units: Mapping[str, str],
        on_start: Callable[[], None] | None = None,
        on_stop: Callable[[], None] | None = None,
    ) -> None:
        self.name = name
        self.units = dict(units)
        self._sample = sample
        self._on_start = on_start
        self._on_stop = on_stop
        super().__init__()

    def _now(self) -> dict[str, float]:
        return dict(self._sample())

    def start(self) -> None:
        if not self._running and self._on_start is not None:
            self._on_start()
        super().start()

    def stop(self) -> None:
        if self._running and self._on_stop is not None:
            self._on_stop()
        super().stop()


class WalltimeClock(Clock):
    """UNIX wall time (the paper's ``gettimeofday``), via a monotonic source."""

    name = "walltime"
    units = {"walltime": "sec"}

    def _now(self) -> dict[str, float]:
        return {"walltime": time.monotonic()}

    def fused_sampler(self):
        return _scalar_sampler(time.monotonic)


# ---------------------------------------------------------------------------
# Process CPU time: on most kernels ``time.process_time`` is a ~100ns vDSO
# read, but on syscall-trapping sandboxes (gVisor and similar) it is a slow
# trap (several microseconds).  The fused hot path therefore reads it through
# a process-wide cache that is refreshed at most once per ``refresh_ns`` —
# calibrated at import: exact (refresh 0) when the source is cheap, ~1 ms
# granularity when it is not.  Totals telescope across windows (marks always
# come from the same monotone cache), so long-run accumulation stays exact to
# within one refresh interval; sub-interval windows see quantized CPU time.
# Override with REPRO_CPUTIME_REFRESH_US (microseconds; 0 forces exact reads).
# ---------------------------------------------------------------------------

def _perf_counter_float() -> float:
    return float(time.perf_counter_ns())


def _scalar_sampler(fn: Callable[[], float]) -> Callable[[], tuple[float]]:
    """Wrap a single-value raw reader for the fused path.  Tagged with
    ``scalar_fn`` so the layout builder can merge runs of adjacent
    single-channel clocks into one closure (fewer calls and allocations)."""

    def sample() -> tuple[float]:
        return (fn(),)

    sample.scalar_fn = fn  # type: ignore[attr-defined]
    return sample


_CPUTIME_CACHE = [0.0, -(10 ** 18)]  # [value_sec, perf_ns at last refresh]
_CPUTIME_REFRESH_LOCK = threading.Lock()


def _calibrate_cputime_refresh_ns() -> int:
    env = os.environ.get("REPRO_CPUTIME_REFRESH_US", "auto")
    if env != "auto":
        try:
            return max(int(float(env) * 1000.0), 0)
        except ValueError:
            return 0
    probe = time.process_time
    perf = time.perf_counter_ns
    # min of individual probes: one scheduler hiccup during calibration must
    # not misclassify a cheap vDSO source as a trapping syscall
    best = float("inf")
    for _ in range(8):
        t0 = perf()
        probe()
        best = min(best, perf() - t0)
    # Cheap vDSO source: sample exactly. Trapping source: 1 ms granularity.
    return 1_000_000 if best > 2_000 else 0


_CPUTIME_REFRESH_NS = _calibrate_cputime_refresh_ns()


def _refresh_cputime_cache(now_ns: int) -> float:
    """Serialized refresh: concurrent refreshers must never write the cache
    backwards (a torn older value would yield negative window deltas)."""
    cache = _CPUTIME_CACHE
    with _CPUTIME_REFRESH_LOCK:
        if now_ns - cache[1] >= _CPUTIME_REFRESH_NS:  # still stale once inside
            value = time.process_time()
            cache[:] = (value, time.perf_counter_ns())
    return cache[0]


def _cputime_cached() -> float:
    now_ns = time.perf_counter_ns()
    cache = _CPUTIME_CACHE
    if now_ns - cache[1] >= _CPUTIME_REFRESH_NS:
        return _refresh_cputime_cache(now_ns)
    return cache[0]


class CPUTimeClock(Clock):
    """Process CPU time (the paper's ``getrusage``: user+system seconds).

    The direct object path (``_now``) always reads the exact source; the fused
    timer path samples through the rate-limited cache described above.
    """

    name = "cputime"
    units = {"cputime": "sec"}

    def _now(self) -> dict[str, float]:
        return {"cputime": time.process_time()}

    def fused_sampler(self):
        if _CPUTIME_REFRESH_NS <= 0:
            # exact mode (cheap vDSO source): read directly, no cache, no lock
            return _scalar_sampler(time.process_time)
        return _scalar_sampler(_cputime_cached)


class ThreadCPUClock(Clock):
    """Per-thread CPU time — useful to separate the driver thread from async
    checkpoint writers."""

    name = "thread_cputime"
    units = {"thread_cputime": "sec"}

    def _now(self) -> dict[str, float]:
        return {"thread_cputime": time.thread_time()}

    def fused_sampler(self):
        return _scalar_sampler(time.thread_time)


class PerfCounterClock(Clock):
    """Highest-resolution counter available (the paper's ``rdtsc`` analogue).

    Reported in nanoseconds; resolution is typically ~20ns on Linux.
    """

    name = "perfcounter"
    units = {"perfcounter": "nsec"}

    def _now(self) -> dict[str, float]:
        return {"perfcounter": float(time.perf_counter_ns())}

    def fused_sampler(self):
        return _scalar_sampler(_perf_counter_float)


class RSSClock(Clock):
    """Resident-set-size high-water delta, read from /proc (Linux).

    Demonstrates a non-time clock per the paper ("clocks are not restricted to
    measure time").  Value is the change in VmRSS over the window, in bytes.
    """

    name = "rss"
    units = {"rss": "bytes"}

    _PAGE = 4096

    def _now(self) -> dict[str, float]:
        try:
            with open("/proc/self/statm") as f:
                parts = f.read().split()
            return {"rss": float(int(parts[1]) * self._PAGE)}
        except (OSError, IndexError, ValueError):  # pragma: no cover
            return {"rss": 0.0}

    def fused_sampler(self):
        now = self._now
        return _scalar_sampler(lambda: now()["rss"])


# ---------------------------------------------------------------------------
# Counter channels: process-global monotonically increasing event counters that
# framework code bumps (checkpoint bytes written, tokens processed, FLOPs of
# executed steps, ...).  A CounterClock snapshots a channel at start/stop, so a
# timer window captures exactly the events that happened inside it.  This is
# the TPU-era stand-in for PAPI event counters.
#
# Storage: one cell per channel, holding a folded ``base`` total plus a
# ``pending`` list of raw amounts.  Writers only ever ``pending.append(x)`` —
# an atomic C-level operation, safe from any thread without a lock.  Readers
# fold ``pending[:n]`` into ``base`` and delete the folded prefix under
# _COUNTER_READ_LOCK; concurrent appends land past the folded prefix and are
# never lost.
#
# Write-only channels (written but never exported through a registered
# CounterClock) have no reader to fold them, so their pending lists are capped:
# ``increment_counter`` self-folds its channel when pending exceeds
# _PENDING_FOLD_CAP (amortized: one locked fold per CAP appends), and the
# fused counter samplers — which already hold the read lock every timer
# window — sweep *all* cells every _PENDING_SWEEP_EVERY passes, folding any
# overflowing cell (this catches raw ``counter_cell`` handles, whose append is
# a bare C call that cannot check anything).  ``fold_pending_counters()`` is
# the explicit maintenance entry point for timer-less processes holding raw
# cells on never-read channels.
# ---------------------------------------------------------------------------

#: fold a channel's pending list once it holds this many unfolded amounts
_PENDING_FOLD_CAP = 4096
#: fused counter samplers sweep all cells for overflow every N sample passes
_PENDING_SWEEP_EVERY = 1024
_SWEEP_STATE = [0]


class _CounterCell:
    __slots__ = ("base", "pending")

    def __init__(self) -> None:
        self.base = 0.0
        self.pending: list[float] = []


_CELLS: dict[str, _CounterCell] = {}
_CELL_APPENDS: dict[str, Callable[[float], None]] = {}
_COUNTER_READ_LOCK = threading.Lock()
_CELLS_CREATE_LOCK = threading.Lock()


def _new_cell(name: str) -> _CounterCell:
    with _CELLS_CREATE_LOCK:
        cell = _CELLS.get(name)
        if cell is None:
            cell = _CounterCell()
            # publish the append before the cell so _CELL_APPENDS lookups in
            # increment_counter never see a cell without its fast path
            _CELL_APPENDS[name] = cell.pending.append
            _CELLS[name] = cell
        return cell


def counter_cell(name: str) -> Callable[[float], None]:
    """Resolve a channel once; returns the lock-free increment callable.

    The returned cell is ``list.append`` bound to the channel's pending list —
    a single C-level call per increment, safe from any thread.  This is the
    recommended hot-loop API (the counter analogue of timer handles)::

        bump = counter_cell("xla_flops")
        ...
        bump(flops_this_step)   # ~50ns, no lock
    """
    cell = _CELL_APPENDS.get(name)
    if cell is None:
        _new_cell(name)
        cell = _CELL_APPENDS[name]
    return cell


def increment_counter(name: str, amount: float) -> None:
    """Add ``amount`` to channel ``name`` (lock-free fast path).

    Name-resolved per call; hot loops should use :func:`counter_cell`.
    ``amount + 0.0`` both coerces ints to float and raises ``TypeError`` here,
    at the call site, for non-numeric input (never poisoning the channel).
    Self-folds the channel when its pending list hits the overflow cap, so a
    write-only channel cannot grow without bound.
    """
    try:
        append = _CELL_APPENDS[name]
    except KeyError:
        _new_cell(name).pending.append(float(amount))
        return
    try:
        append(amount + 0.0)
    except TypeError:
        append(float(amount))  # e.g. numeric strings
    # bound write-only channels: the bound append's __self__ IS the pending
    # list, so the overflow probe costs one attribute read + len
    pending = append.__self__
    if len(pending) >= _PENDING_FOLD_CAP:
        with _COUNTER_READ_LOCK:
            _fold_cell_locked(_CELLS[name])


def fold_pending_counters() -> None:
    """Fold every channel's pending amounts into its base total now.

    Maintenance entry point for processes that hold raw :func:`counter_cell`
    handles on channels no registered clock ever reads *and* never run a
    timer window (which would sweep them): call this periodically to keep
    those pending lists bounded.  Totals are unchanged.
    """
    with _COUNTER_READ_LOCK:
        for cell in list(_CELLS.values()):
            _fold_cell_locked(cell)


def _sweep_overflow_locked() -> None:
    """Fold any cell whose pending list overflowed; read lock held."""
    for cell in list(_CELLS.values()):
        if len(cell.pending) >= _PENDING_FOLD_CAP:
            _fold_cell_locked(cell)


def _fold_cells_into(append: Callable[[float], None], cells) -> None:
    """Fold each cell's pending amounts into its base total and emit the
    totals via ``append``.  Caller holds the read lock.

    This is the single fold implementation shared by the name-based readers
    and every fused sampler, so the semantics below hold everywhere:
    ``len``/slice-copy/``del prefix`` are each atomic; concurrent appends go
    past index ``n`` and survive the prefix delete, so no update is lost.
    Non-numeric values (possible only through a raw :func:`counter_cell`
    handle, which skips call-site validation) are dropped rather than left to
    poison every later read of the channel.
    """
    for cell in cells:
        pending = cell.pending
        n = len(pending)
        if n:
            chunk = pending[:n]
            del pending[:n]
            try:
                cell.base += float(sum(chunk))
            except TypeError:
                cell.base += float(
                    sum(x for x in chunk if isinstance(x, (int, float)))
                )
        append(cell.base)


def _fold_cell_locked(cell: _CounterCell) -> float:
    """One cell's folded total; caller holds the read lock."""
    out: list[float] = []
    _fold_cells_into(out.append, (cell,))
    return out[0]


def counter_channel(name: str) -> float:
    with _COUNTER_READ_LOCK:
        cell = _CELLS.get(name)
        return _fold_cell_locked(cell) if cell is not None else 0.0


def counter_values(names: Sequence[str]) -> list[float]:
    """Merged totals for several channels in one read-lock acquisition."""
    with _COUNTER_READ_LOCK:
        cells = _CELLS
        out = []
        for name in names:
            cell = cells.get(name)
            out.append(_fold_cell_locked(cell) if cell is not None else 0.0)
        return out


def counter_names() -> list[str]:
    """Every counter channel created so far, sorted — the enumeration hook
    exporters use (channels are created on first write and never deleted)."""
    with _CELLS_CREATE_LOCK:
        return sorted(_CELLS)


def counter_stats() -> dict[str, int]:
    """Boundedness introspection over the counter store:
    ``{"channels", "pending_total", "pending_max"}``.

    ``pending_*`` count *unfolded* amounts — by design each channel's pending
    list stays under ``_PENDING_FOLD_CAP`` (readers fold, writers self-fold at
    the cap, fused samplers sweep), so a pending total that keeps climbing
    means some path defeats all three folds.  The metrics exporter publishes
    these and the soak gate asserts they stay flat; the timer-side counterpart
    is :meth:`repro.core.timers.TimerDB.cardinality`.
    """
    with _COUNTER_READ_LOCK:
        pending = [len(cell.pending) for cell in _CELLS.values()]
    return {
        "channels": len(pending),
        "pending_total": sum(pending),
        "pending_max": max(pending, default=0),
    }


def _make_counter_sampler(names: tuple[str, ...]) -> Callable[[], list[float]]:
    """Fused sampler over counter channels: one read-lock acquisition, folds
    inlined, cells resolved once at layout build (cells are never deleted).
    Tagged with ``counter_names`` so the layout builder can merge adjacent
    counter clocks into a single lock acquisition per sample pass."""
    lock = _COUNTER_READ_LOCK
    cells = tuple(_new_cell(name) for name in names)
    fold = _fold_cells_into
    sweep_state = _SWEEP_STATE

    def sample() -> list[float]:
        out: list[float] = []
        with lock:
            fold(out.append, cells)
            tick = sweep_state[0] + 1
            if tick >= _PENDING_SWEEP_EVERY:
                sweep_state[0] = 0
                _sweep_overflow_locked()
            else:
                sweep_state[0] = tick
        return out

    sample.counter_names = names  # type: ignore[attr-defined]
    return sample


class CounterClock(Clock):
    """Clock over one or more global counter channels."""

    def __init__(self, name: str, channels: Mapping[str, str]) -> None:
        self.name = name
        self.units = dict(channels)
        super().__init__()

    def _now(self) -> dict[str, float]:
        names = tuple(self.units)
        return dict(zip(names, counter_values(names)))

    def fused_sampler(self):
        return _make_counter_sampler(tuple(self.units))


# ---------------------------------------------------------------------------
# Registry ("Cactus's standard registration techniques"): clock factories are
# registered by name; every Timer created afterwards instantiates all of them.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Clock]] = {}
_REGISTRY_LOCK = threading.Lock()
_REGISTRY_VERSION = [0]


def register_clock(name: str, factory: Callable[[], Clock]) -> None:
    """Register a clock factory.  Registering an existing name replaces it
    (steerable at runtime, like Cactus parameters)."""
    with _REGISTRY_LOCK:
        _REGISTRY[name] = factory
        _REGISTRY_VERSION[0] += 1


def unregister_clock(name: str) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.pop(name, None)
        _REGISTRY_VERSION[0] += 1


def clock_names() -> list[str]:
    with _REGISTRY_LOCK:
        return sorted(_REGISTRY.keys())


def registry_version() -> int:
    # Lock-free: a single list-element read is atomic under the GIL, and the
    # version is monotone — the timer fast path polls this every window.
    return _REGISTRY_VERSION[0]


def make_clock(name: str) -> Clock:
    with _REGISTRY_LOCK:
        factory = _REGISTRY[name]
    return factory()


def make_all_clocks() -> dict[str, Clock]:
    with _REGISTRY_LOCK:
        factories = dict(_REGISTRY)
    return {name: factory() for name, factory in factories.items()}


# ---------------------------------------------------------------------------
# Channel layout: the resolved struct-of-arrays schema of the current registry.
# One flat float slot per channel of every fused clock, in registration order;
# clocks without a fused sampler are listed for the per-timer slow path.  Flat
# channel names are collision-namespaced: when two clocks export the same
# channel name, every colliding export is renamed ``<clock>.<channel>`` so no
# reading silently overwrites another in flattened views.
# ---------------------------------------------------------------------------


class ChannelLayout:
    """Immutable resolved layout for one registry version (shared by all
    timers; rebuild is triggered by a version-stamp mismatch)."""

    __slots__ = (
        "version",
        "sample",
        "n_fused",
        "fused_keys",
        "fused_flat",
        "key_index",
        "flat_index",
        "clock_meta",
        "nonfused_names",
        "nonfused_flat",
        "walltime_index",
    )

    def __init__(
        self,
        version: int,
        samplers: list[Callable[[], Sequence[float]]],
        fused_keys: list[tuple[str, str]],
        fused_flat: list[str],
        clock_meta: list[tuple[str, slice, tuple[str, ...], dict[str, str]]],
        nonfused_names: list[str],
        nonfused_flat: dict[str, dict[str, str]],
    ) -> None:
        self.version = version
        self.n_fused = len(fused_keys)
        self.fused_keys = tuple(fused_keys)
        self.fused_flat = tuple(fused_flat)
        self.key_index = {key: i for i, key in enumerate(fused_keys)}
        self.flat_index = {name: i for i, name in enumerate(fused_flat)}
        self.clock_meta = tuple(clock_meta)
        self.nonfused_names = tuple(nonfused_names)
        self.nonfused_flat = nonfused_flat
        self.walltime_index = self.key_index.get(("walltime", "walltime"))
        if self.walltime_index is None:
            self.walltime_index = self.flat_index.get("walltime")
        fns = tuple(samplers)

        if (
            len(fns) == 2
            and getattr(fns[0], "time3", False)
            and getattr(fns[1], "counter_names", None) is not None
        ):
            # the default registry shape: collapse to one closure, zero
            # composition overhead on the hot path
            fns = (
                _make_default_sampler(
                    fns[1].counter_names,
                    exact_cpu=getattr(fns[0], "exact_cpu", False),
                ),
            )

        if len(fns) == 0:

            def sample() -> list[float]:
                return []

        elif len(fns) == 1:
            single = fns[0]

            def sample() -> list[float]:
                return list(single())

        elif len(fns) == 2:
            first, second = fns

            def sample() -> list[float]:
                return [*first(), *second()]

        else:

            def sample() -> list[float]:
                out: list[float] = []
                for fn in fns:
                    out += fn()
                return out

        self.sample = sample


_LAYOUT_CACHE: dict[int, ChannelLayout] = {}


def channel_layout() -> ChannelLayout:
    """The resolved layout for the current registry version (cached)."""
    version = _REGISTRY_VERSION[0]
    cached = _LAYOUT_CACHE.get(version)
    if cached is not None:
        return cached
    with _REGISTRY_LOCK:
        version = _REGISTRY_VERSION[0]
        factories = list(_REGISTRY.items())
    layout = _build_layout(version, factories)
    if len(_LAYOUT_CACHE) > 8:  # keep the cache tiny; stale versions are dead
        _LAYOUT_CACHE.clear()
    _LAYOUT_CACHE[version] = layout
    return layout


def _time3_sampler(
    mono=time.monotonic,
    perf=time.perf_counter_ns,
    cache=_CPUTIME_CACHE,
) -> tuple[float, float, float]:
    """Hand-fused walltime/cputime/perfcounter pass for the default layout:
    one perf_counter read serves both the perfcounter channel and the cputime
    cache age check."""
    p = perf()
    if p - cache[1] >= _CPUTIME_REFRESH_NS:
        cpu = _refresh_cputime_cache(p)
    else:
        cpu = cache[0]
    return (mono(), cpu, float(p))


_time3_sampler.time3 = True  # type: ignore[attr-defined]


def _time3_exact_sampler(
    mono=time.monotonic,
    cpu=time.process_time,
    perf=time.perf_counter_ns,
) -> tuple[float, float, float]:
    """Exact-mode variant of :func:`_time3_sampler` for kernels where the
    CPU-time source is a cheap vDSO read: no cache, no lock."""
    return (mono(), cpu(), float(perf()))


_time3_exact_sampler.time3 = True  # type: ignore[attr-defined]
_time3_exact_sampler.exact_cpu = True  # type: ignore[attr-defined]


def _make_default_sampler(
    names: tuple[str, ...],
    exact_cpu: bool,
    mono=time.monotonic,
    perf=time.perf_counter_ns,
    cpu_read=time.process_time,
    cache=_CPUTIME_CACHE,
) -> Callable[[], list[float]]:
    """Fully fused single closure for the default registry shape
    (walltime/cputime/perfcounter followed by counter clocks): one call, one
    output list, no composition loop."""
    lock = _COUNTER_READ_LOCK
    cells = tuple(_new_cell(name) for name in names)
    fold = _fold_cells_into
    sweep_state = _SWEEP_STATE

    def sample() -> list[float]:
        p = perf()
        if exact_cpu:
            cpu = cpu_read()
        elif p - cache[1] >= _CPUTIME_REFRESH_NS:
            cpu = _refresh_cputime_cache(p)
        else:
            cpu = cache[0]
        out = [mono(), cpu, float(p)]
        with lock:
            fold(out.append, cells)
            tick = sweep_state[0] + 1
            if tick >= _PENDING_SWEEP_EVERY:
                sweep_state[0] = 0
                _sweep_overflow_locked()
            else:
                sweep_state[0] = tick
        return out

    return sample


def _merge_scalar_run(fns: list[Callable[[], float]]) -> Callable[[], Sequence[float]]:
    if fns == [time.monotonic, _cputime_cached, _perf_counter_float]:
        return _time3_sampler
    if fns == [time.monotonic, time.process_time, _perf_counter_float]:
        return _time3_exact_sampler
    n = len(fns)
    if n == 1:
        f = fns[0]
        return lambda: (f(),)
    if n == 2:
        f, g = fns
        return lambda: (f(), g())
    if n == 3:
        f, g, h = fns
        return lambda: (f(), g(), h())
    if n == 4:
        f, g, h, k = fns
        return lambda: (f(), g(), h(), k())
    frozen = tuple(fns)
    return lambda: [fn() for fn in frozen]


def _merge_samplers(
    samplers: list[Callable[[], Sequence[float]]],
) -> list[Callable[[], Sequence[float]]]:
    """Fuse runs of adjacent mergeable samplers.

    Channel slots of adjacent clocks are contiguous in the flat layout, so a
    merged sampler emits the concatenated values in place of the run: runs of
    counter clocks share one read-lock acquisition; runs of single-value raw
    readers (the built-in time clocks) share one closure call and one tuple.
    """
    merged: list[Callable[[], Sequence[float]]] = []
    counter_run: list[str] = []
    scalar_run: list[Callable[[], float]] = []

    def flush() -> None:
        if counter_run:
            merged.append(_make_counter_sampler(tuple(counter_run)))
            counter_run.clear()
        if scalar_run:
            merged.append(_merge_scalar_run(list(scalar_run)))
            scalar_run.clear()

    for sampler in samplers:
        names = getattr(sampler, "counter_names", None)
        scalar = getattr(sampler, "scalar_fn", None)
        if names is not None:
            if scalar_run:
                flush()
            counter_run.extend(names)
        elif scalar is not None:
            if counter_run:
                flush()
            scalar_run.append(scalar)
        else:
            flush()
            merged.append(sampler)
    flush()
    return merged


def _build_layout(
    version: int, factories: list[tuple[str, Callable[[], Clock]]]
) -> ChannelLayout:
    prototypes: list[tuple[str, Clock]] = [(name, factory()) for name, factory in factories]

    # collision detection across every clock's exported channels
    seen: dict[str, int] = {}
    for _, proto in prototypes:
        for ch in proto._channels():
            seen[ch] = seen.get(ch, 0) + 1

    def flat_name(clock_name: str, channel: str) -> str:
        return f"{clock_name}.{channel}" if seen.get(channel, 0) > 1 else channel

    samplers: list[Callable[[], Sequence[float]]] = []
    fused_keys: list[tuple[str, str]] = []
    fused_flat: list[str] = []
    clock_meta: list[tuple[str, slice, tuple[str, ...], dict[str, str]]] = []
    nonfused_names: list[str] = []
    nonfused_flat: dict[str, dict[str, str]] = {}

    for name, proto in prototypes:
        channels = tuple(proto._channels())
        sampler = proto.fused_sampler()
        if sampler is not None and channels:
            # one-time arity check: a mis-sized user sampler would silently
            # shift every later clock's values onto wrong channel slots
            probe = tuple(sampler())
            if len(probe) != len(channels):
                raise ValueError(
                    f"clock {name!r}: fused_sampler returned {len(probe)} "
                    f"values for {len(channels)} channels {channels}"
                )
        if sampler is None or not channels:
            nonfused_names.append(name)
            nonfused_flat[name] = {ch: flat_name(name, ch) for ch in channels}
            continue
        lo = len(fused_keys)
        samplers.append(sampler)
        for ch in channels:
            fused_keys.append((name, ch))
            fused_flat.append(flat_name(name, ch))
        clock_meta.append((name, slice(lo, len(fused_keys)), channels, dict(proto.units)))

    return ChannelLayout(
        version=version,
        samplers=_merge_samplers(samplers),
        fused_keys=fused_keys,
        fused_flat=fused_flat,
        clock_meta=clock_meta,
        nonfused_names=nonfused_names,
        nonfused_flat=nonfused_flat,
    )


def reset_default_clocks(extra: bool = False) -> None:
    """(Re-)install the built-in clock set.

    ``extra=True`` additionally installs the noisier clocks (rss, thread cpu).
    The device-event counters are always installed; they read 0 until the
    framework bumps their channels.
    """
    with _REGISTRY_LOCK:
        _REGISTRY.clear()
        _REGISTRY_VERSION[0] += 1
    register_clock("walltime", WalltimeClock)
    register_clock("cputime", CPUTimeClock)
    register_clock("perfcounter", PerfCounterClock)
    register_clock(
        "xla_device",
        lambda: CounterClock(
            "xla_device", {"xla_flops": "flop", "xla_bytes": "bytes"}
        ),
    )
    register_clock(
        "io",
        lambda: CounterClock("io", {"io_bytes": "bytes", "io_ops": "count"}),
    )
    if extra:
        register_clock("rss", RSSClock)
        register_clock("thread_cputime", ThreadCPUClock)


# Install defaults at import time (cheap; tests may reinstall).
reset_default_clocks(extra=os.environ.get("REPRO_EXTRA_CLOCKS", "0") == "1")
