"""Clocks — the low-level measurement entities of the timing infrastructure.

Faithful to the paper (Sec. 2, Tables 1-2): a *clock* is an object created from a
set of callbacks (``create/destroy/start/stop/read/reset/get/set``) that measures
"any kind of event" — wall time, CPU time, cycle counters, or discrete events such
as I/O bytes or FLOPs executed.  Clocks are registered with the infrastructure via
a standard registration mechanism so new metrics require *no modification to any
existing timing code*: every :class:`~repro.core.timers.Timer` automatically
encapsulates one instance of every registered clock.

Hardware adaptation (see DESIGN.md): TPUs expose no user-readable PMU, so the
PAPI-analogue clocks here are *derived* device clocks (``xla_flops``/``xla_bytes``)
fed by XLA's compiled cost analysis, plus generic :class:`CounterClock` channels
for framework events (checkpoint bytes, collective bytes, tokens processed).
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence

__all__ = [
    "Clock",
    "ClockValues",
    "CallbackClock",
    "WalltimeClock",
    "CPUTimeClock",
    "PerfCounterClock",
    "ThreadCPUClock",
    "RSSClock",
    "CounterClock",
    "register_clock",
    "unregister_clock",
    "clock_names",
    "make_clock",
    "make_all_clocks",
    "counter_channel",
    "increment_counter",
    "reset_default_clocks",
]


@dataclass
class ClockValues:
    """A multi-valued clock reading (a clock can measure several values at once,
    e.g. multiple PAPI counters)."""

    values: Dict[str, float]
    units: Dict[str, str]

    def __getitem__(self, key: str) -> float:
        return self.values[key]

    def scalar(self) -> float:
        """The clock's primary value (first channel)."""
        return next(iter(self.values.values())) if self.values else 0.0


class Clock:
    """Base clock.  Subclasses implement ``_now() -> dict`` returning the current
    raw counter values; accumulation across start/stop windows is handled here so
    that a clock can be started and stopped many times, with ``read`` returning
    the accumulated measure (Cactus semantics: reset sets accumulation to zero).
    """

    #: registry name; subclasses override.
    name: str = "abstract"
    #: units per channel.
    units: Mapping[str, str] = {}

    def __init__(self) -> None:
        self._running = False
        self._accum: Dict[str, float] = {}
        self._mark: Dict[str, float] = {}

    # -- core sampling hook -------------------------------------------------
    def _now(self) -> Dict[str, float]:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- Cactus clock API ----------------------------------------------------
    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._mark = self._now()

    def stop(self) -> None:
        if not self._running:
            return
        now = self._now()
        for key, value in now.items():
            self._accum[key] = self._accum.get(key, 0.0) + (value - self._mark.get(key, 0.0))
        self._running = False

    def reset(self) -> None:
        self._accum = {}
        if self._running:
            self._mark = self._now()

    def read(self) -> ClockValues:
        values = dict(self._accum)
        if self._running:
            now = self._now()
            for key, value in now.items():
                values[key] = values.get(key, 0.0) + (value - self._mark.get(key, 0.0))
        for key in self._channels():
            values.setdefault(key, 0.0)
        return ClockValues(values=values, units=dict(self.units))

    # Cactus `get`/`set`: direct access to the accumulator.
    def get(self) -> Dict[str, float]:
        return self.read().values

    def set(self, values: Mapping[str, float]) -> None:
        self._accum = dict(values)
        if self._running:
            self._mark = self._now()

    def destroy(self) -> None:
        self._running = False
        self._accum = {}

    @property
    def is_running(self) -> bool:
        return self._running

    def _channels(self) -> Sequence[str]:
        return tuple(self.units.keys())


class CallbackClock(Clock):
    """A clock built from user callbacks — the paper's extension mechanism.

    ``sample`` returns the raw counter values; optional ``on_start``/``on_stop``
    callbacks allow clocks that must arm hardware counters.
    """

    def __init__(
        self,
        name: str,
        sample: Callable[[], Mapping[str, float]],
        units: Mapping[str, str],
        on_start: Optional[Callable[[], None]] = None,
        on_stop: Optional[Callable[[], None]] = None,
    ) -> None:
        self.name = name
        self.units = dict(units)
        self._sample = sample
        self._on_start = on_start
        self._on_stop = on_stop
        super().__init__()

    def _now(self) -> Dict[str, float]:
        return dict(self._sample())

    def start(self) -> None:
        if not self._running and self._on_start is not None:
            self._on_start()
        super().start()

    def stop(self) -> None:
        if self._running and self._on_stop is not None:
            self._on_stop()
        super().stop()


class WalltimeClock(Clock):
    """UNIX wall time (the paper's ``gettimeofday``), via a monotonic source."""

    name = "walltime"
    units = {"walltime": "sec"}

    def _now(self) -> Dict[str, float]:
        return {"walltime": time.monotonic()}


class CPUTimeClock(Clock):
    """Process CPU time (the paper's ``getrusage``: user+system seconds)."""

    name = "cputime"
    units = {"cputime": "sec"}

    def _now(self) -> Dict[str, float]:
        return {"cputime": time.process_time()}


class ThreadCPUClock(Clock):
    """Per-thread CPU time — useful to separate the driver thread from async
    checkpoint writers."""

    name = "thread_cputime"
    units = {"thread_cputime": "sec"}

    def _now(self) -> Dict[str, float]:
        return {"thread_cputime": time.thread_time()}


class PerfCounterClock(Clock):
    """Highest-resolution counter available (the paper's ``rdtsc`` analogue).

    Reported in nanoseconds; resolution is typically ~20ns on Linux.
    """

    name = "perfcounter"
    units = {"perfcounter": "nsec"}

    def _now(self) -> Dict[str, float]:
        return {"perfcounter": float(time.perf_counter_ns())}


class RSSClock(Clock):
    """Resident-set-size high-water delta, read from /proc (Linux).

    Demonstrates a non-time clock per the paper ("clocks are not restricted to
    measure time").  Value is the change in VmRSS over the window, in bytes.
    """

    name = "rss"
    units = {"rss": "bytes"}

    _PAGE = 4096

    def _now(self) -> Dict[str, float]:
        try:
            with open("/proc/self/statm", "r") as f:
                parts = f.read().split()
            return {"rss": float(int(parts[1]) * self._PAGE)}
        except (OSError, IndexError, ValueError):  # pragma: no cover
            return {"rss": 0.0}


# ---------------------------------------------------------------------------
# Counter channels: process-global monotonically increasing event counters that
# framework code bumps (checkpoint bytes written, tokens processed, FLOPs of
# executed steps, ...).  A CounterClock snapshots a channel at start/stop, so a
# timer window captures exactly the events that happened inside it.  This is
# the TPU-era stand-in for PAPI event counters.
# ---------------------------------------------------------------------------

_COUNTERS: Dict[str, float] = {}
_COUNTER_LOCK = threading.Lock()


def counter_channel(name: str) -> float:
    with _COUNTER_LOCK:
        return _COUNTERS.get(name, 0.0)


def increment_counter(name: str, amount: float) -> None:
    with _COUNTER_LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0.0) + float(amount)


class CounterClock(Clock):
    """Clock over one or more global counter channels."""

    def __init__(self, name: str, channels: Mapping[str, str]) -> None:
        self.name = name
        self.units = dict(channels)
        super().__init__()

    def _now(self) -> Dict[str, float]:
        return {ch: counter_channel(ch) for ch in self.units}


# ---------------------------------------------------------------------------
# Registry ("Cactus's standard registration techniques"): clock factories are
# registered by name; every Timer created afterwards instantiates all of them.
# ---------------------------------------------------------------------------

_REGISTRY: "Dict[str, Callable[[], Clock]]" = {}
_REGISTRY_LOCK = threading.Lock()
_REGISTRY_VERSION = [0]


def register_clock(name: str, factory: Callable[[], Clock]) -> None:
    """Register a clock factory.  Registering an existing name replaces it
    (steerable at runtime, like Cactus parameters)."""
    with _REGISTRY_LOCK:
        _REGISTRY[name] = factory
        _REGISTRY_VERSION[0] += 1


def unregister_clock(name: str) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.pop(name, None)
        _REGISTRY_VERSION[0] += 1


def clock_names() -> List[str]:
    with _REGISTRY_LOCK:
        return sorted(_REGISTRY.keys())


def registry_version() -> int:
    with _REGISTRY_LOCK:
        return _REGISTRY_VERSION[0]


def make_clock(name: str) -> Clock:
    with _REGISTRY_LOCK:
        factory = _REGISTRY[name]
    return factory()


def make_all_clocks() -> Dict[str, Clock]:
    with _REGISTRY_LOCK:
        factories = dict(_REGISTRY)
    return {name: factory() for name, factory in factories.items()}


def reset_default_clocks(extra: bool = False) -> None:
    """(Re-)install the built-in clock set.

    ``extra=True`` additionally installs the noisier clocks (rss, thread cpu).
    The device-event counters are always installed; they read 0 until the
    framework bumps their channels.
    """
    with _REGISTRY_LOCK:
        _REGISTRY.clear()
        _REGISTRY_VERSION[0] += 1
    register_clock("walltime", WalltimeClock)
    register_clock("cputime", CPUTimeClock)
    register_clock("perfcounter", PerfCounterClock)
    register_clock(
        "xla_device",
        lambda: CounterClock(
            "xla_device", {"xla_flops": "flop", "xla_bytes": "bytes"}
        ),
    )
    register_clock(
        "io",
        lambda: CounterClock("io", {"io_bytes": "bytes", "io_ops": "count"}),
    )
    if extra:
        register_clock("rss", RSSClock)
        register_clock("thread_cputime", ThreadCPUClock)


# Install defaults at import time (cheap; tests may reinstall).
reset_default_clocks(extra=os.environ.get("REPRO_EXTRA_CLOCKS", "0") == "1")
