"""Timer reports (paper Sec. 3.1, Fig. 2) — human tables, JSON logs, periodic output.

``format_report`` renders the Fig.-2-style table: one row per timer, one column
per clock channel, grouped by schedule bin, with a "Total time for simulation"
footer.  ``TimerLogger`` appends JSON snapshots to a log file ("logged
semi-automatically for post-mortem review").
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Mapping, Optional, Sequence

from .timers import TimerDB, timer_db

__all__ = [
    "format_report",
    "report_rows",
    "straggler_rows",
    "TimerLogger",
    "bin_distribution",
]


def _channel_value(flat: Dict[str, float], channel: str) -> float:
    """Look up a flat channel, tolerating collision-namespaced layouts.

    When two clocks export the same channel name the snapshot renames every
    colliding export ``<clock>.<channel>``; a report column asked for by plain
    name then takes the *first* namespaced export (layout order, i.e. clock
    registration order).  Colliding clocks frequently read the same underlying
    source, so summing would double-count; picking one is deterministic and
    right whenever the sources agree.
    """
    value = flat.get(channel)
    if value is not None:
        return value
    suffix = "." + channel
    for key, v in flat.items():
        if key.endswith(suffix):
            return v
    return 0.0


def report_rows(
    db: Optional[TimerDB] = None,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "",
) -> List[Dict[str, object]]:
    db = db if db is not None else timer_db()
    rows: List[Dict[str, object]] = []
    for timer in db.timers():
        if prefix and not timer.name.startswith(prefix):
            continue
        flat = timer.read_flat()
        row: Dict[str, object] = {"timer": timer.name, "count": timer.count}
        for ch in channels:
            row[ch] = _channel_value(flat, ch)
        rows.append(row)
    return rows


def straggler_rows(
    detector,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "DIST",
) -> List[Dict[str, object]]:
    """Fleet-health rows from a ``repro.dist.stragglers.StragglerDetector``.

    Shaped exactly like :func:`report_rows` entries (one row per reporting
    host, walltime = that host's total step seconds) for JSON summaries and
    monitor endpoints; hosts flagged by the detector's most recent check are
    tagged ``[STRAGGLER]``.  The Fig.-2 table itself needs no merging — the
    detector's ``check()`` publishes ``DIST/host{h}::step`` timers straight
    into the timer DB, which :func:`format_report` renders like any other
    timer.  Duck-typed (needs ``host_stats()``/``reports``) to keep ``core``
    free of a ``dist`` import.
    """
    latest = detector.reports[-1] if getattr(detector, "reports", None) else None
    rows: List[Dict[str, object]] = []
    for host, (count, total) in sorted(detector.host_stats().items()):
        name = f"{prefix}/host{host}::step"
        if latest is not None and host in latest.stragglers:
            name += " [STRAGGLER]"
        row: Dict[str, object] = {"timer": name, "count": count}
        for ch in channels:
            row[ch] = total if ch == "walltime" else 0.0
        rows.append(row)
    return rows


def format_report(
    db: Optional[TimerDB] = None,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "",
    title: str = "Timer report",
) -> str:
    """Render the standard timer report (cf. paper Fig. 2)."""
    db = db if db is not None else timer_db()
    rows = report_rows(db, channels, prefix)
    name_w = max([len(r["timer"]) for r in rows] + [len("Timer")]) + 2
    col_w = 22
    lines = [title, "=" * (name_w + (col_w + 1) * (len(channels) + 1))]
    header = "Timer".ljust(name_w) + "count".rjust(col_w)
    for ch in channels:
        header += " " + ch.rjust(col_w)
    lines.append(header)
    lines.append("-" * len(header))
    for row in sorted(rows, key=lambda r: r["timer"]):
        line = str(row["timer"]).ljust(name_w) + str(row["count"]).rjust(col_w)
        for ch in channels:
            line += " " + f"{float(row.get(ch, 0.0)):.8f}"[:col_w].rjust(col_w)
        lines.append(line)
    total = db.get("simulation/total").read_flat() if db.exists("simulation/total") else {}
    if total:
        lines.append("-" * len(header))
        line = "Total time for simulation".ljust(name_w) + "".rjust(col_w)
        for ch in channels:
            line += " " + f"{_channel_value(total, ch):.8f}"[:col_w].rjust(col_w)
        lines.append(line)
    return "\n".join(lines)


def bin_distribution(db: Optional[TimerDB] = None) -> Dict[str, float]:
    """Wall-time distribution over schedule bins (paper Fig. 1 right)."""
    db = db if db is not None else timer_db()
    out: Dict[str, float] = {}
    for timer in db.timers():
        if timer.name.startswith("bin/"):
            out[timer.name[len("bin/"):]] = timer.seconds()
    return out


class TimerLogger:
    """Appends timer-DB snapshots as JSON lines for post-mortem review."""

    def __init__(self, path: str, db: Optional[TimerDB] = None) -> None:
        self.path = path
        self._db = db if db is not None else timer_db()
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)

    def log(self, iteration: int, extra: Optional[Mapping[str, object]] = None) -> None:
        record = {
            "t": time.time(),
            "iteration": iteration,
            "timers": self._db.snapshot(),
        }
        if extra:
            record["extra"] = dict(extra)
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")

    def read_all(self) -> List[dict]:
        if not os.path.exists(self.path):
            return []
        with open(self.path) as f:
            return [json.loads(line) for line in f if line.strip()]
