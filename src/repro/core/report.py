"""Timer reports (paper Sec. 3.1, Fig. 2) — human tables, JSON logs, periodic output.

``format_report`` renders the Fig.-2-style table: one row per timer, one column
per clock channel, grouped by schedule bin, with a "Total time for simulation"
footer — and, when handed a control loop, an ``ADAPT/`` section recording
every runtime-adaptation decision (when, trigger channel, action taken).
``TimerLogger`` appends JSON snapshots to a log file ("logged
semi-automatically for post-mortem review").
"""

from __future__ import annotations

import json
import os
import time
from collections.abc import Mapping, Sequence

from .timers import TimerDB, TimerNode, path_matches, timer_db

__all__ = [
    "format_report",
    "format_tree_report",
    "report_rows",
    "tree_rows",
    "straggler_rows",
    "adapt_rows",
    "format_adapt_report",
    "TimerLogger",
    "bin_distribution",
]


def _channel_value(flat: dict[str, float], channel: str) -> float:
    """Look up a flat channel, tolerating collision-namespaced layouts.

    When two clocks export the same channel name the snapshot renames every
    colliding export ``<clock>.<channel>``; a report column asked for by plain
    name then takes the *first* namespaced export (layout order, i.e. clock
    registration order).  Colliding clocks frequently read the same underlying
    source, so summing would double-count; picking one is deterministic and
    right whenever the sources agree.
    """
    value = flat.get(channel)
    if value is not None:
        return value
    suffix = "." + channel
    for key, v in flat.items():
        if key.endswith(suffix):
            return v
    return 0.0


def report_rows(
    db: TimerDB | None = None,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "",
) -> list[dict[str, object]]:
    db = db if db is not None else timer_db()
    rows: list[dict[str, object]] = []
    for timer in db.timers():
        if prefix and not path_matches(timer.name, prefix):
            continue
        flat = timer.read_flat()
        row: dict[str, object] = {"timer": timer.name, "count": timer.count}
        for ch in channels:
            row[ch] = _channel_value(flat, ch)
        rows.append(row)
    return rows


def straggler_rows(
    detector,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "DIST",
) -> list[dict[str, object]]:
    """Fleet-health rows from a ``repro.dist.stragglers.StragglerDetector``.

    Shaped exactly like :func:`report_rows` entries (one row per reporting
    host, walltime = that host's total step seconds) for JSON summaries and
    monitor endpoints; hosts flagged by the detector's most recent check are
    tagged ``[STRAGGLER]`` and hosts removed from the fleet ``[EVICTED]``.
    The Fig.-2 table itself needs no merging — the detector's ``check()``
    publishes ``DIST/host{h}::step`` timers straight into the timer DB, which
    :func:`format_report` renders like any other timer.  Duck-typed (needs
    ``host_stats()``/``reports``) to keep ``core`` free of a ``dist`` import.
    """
    latest = detector.reports[-1] if getattr(detector, "reports", None) else None
    evicted = getattr(detector, "evicted", ()) or ()
    rows: list[dict[str, object]] = []
    for host, (count, total) in sorted(detector.host_stats().items()):
        name = f"{prefix}/host{host}::step"
        if host in evicted:
            name += " [EVICTED]"
        elif latest is not None and host in latest.stragglers:
            name += " [STRAGGLER]"
        row: dict[str, object] = {"timer": name, "count": count}
        for ch in channels:
            row[ch] = total if ch == "walltime" else 0.0
        rows.append(row)
    return rows


def adapt_rows(loop) -> list[dict[str, object]]:
    """Decision-log rows from a ``repro.adapt.ControlLoop``.

    One row per recorded :class:`~repro.adapt.controller.ControlAction` —
    when (step), who (controller), what (action), why (trigger channel), and
    the action's parameters — for JSON summaries and monitor endpoints.
    Duck-typed (needs ``.actions``) to keep ``core`` free of an ``adapt``
    import; the aggregate ``ADAPT/<controller>::<action>`` count rows are
    published into the timer DB by the loop itself.
    """
    return [
        {
            "step": a.step,
            "controller": a.controller,
            "action": a.action,
            "trigger": a.trigger,
            "detail": dict(a.detail),
        }
        for a in getattr(loop, "actions", ())
    ]


def format_adapt_report(loop, title: str = "ADAPT decisions") -> str:
    """Render the control loop's decision log as a table (the ``ADAPT/``
    section of the Fig.-2 report): one line per decision with the step it
    fired on, the controller, the action taken, and the trigger channel."""
    rows = adapt_rows(loop)
    header = f"{title} ({len(rows)})"
    if not rows:
        return f"{header}\n{'=' * len(header)}\n(no adaptation decisions recorded)"
    step_w = max(len("step"), *(len(str(r["step"])) for r in rows))
    ctrl_w = max(len("controller"), *(len(str(r["controller"])) for r in rows)) + 2
    act_w = max(len("action"), *(len(str(r["action"])) for r in rows)) + 2
    trig_w = max(len("trigger"), *(len(str(r["trigger"])) for r in rows)) + 2
    lines = [header, "=" * len(header)]
    lines.append(
        "step".rjust(step_w)
        + "  " + "controller".ljust(ctrl_w)
        + "action".ljust(act_w)
        + "trigger".ljust(trig_w)
        + "detail"
    )
    lines.append("-" * len(lines[-1]))
    for r in rows:
        detail = " ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in r["detail"].items()
        )
        lines.append(
            str(r["step"]).rjust(step_w)
            + "  " + str(r["controller"]).ljust(ctrl_w)
            + str(r["action"]).ljust(act_w)
            + str(r["trigger"]).ljust(trig_w)
            + detail
        )
    return "\n".join(lines)


def format_report(
    db: TimerDB | None = None,
    channels: Sequence[str] = ("walltime", "cputime"),
    prefix: str = "",
    title: str = "Timer report",
    adapt=None,
) -> str:
    """Render the standard timer report (cf. paper Fig. 2).

    Pass a ``repro.adapt.ControlLoop`` as ``adapt`` to append the ``ADAPT/``
    decision-log section (every runtime adaptation: when, trigger channel,
    action taken) under the timer table.
    """
    db = db if db is not None else timer_db()
    rows = report_rows(db, channels, prefix)
    name_w = max([len(r["timer"]) for r in rows] + [len("Timer")]) + 2
    col_w = 22
    lines = [title, "=" * (name_w + (col_w + 1) * (len(channels) + 1))]
    header = "Timer".ljust(name_w) + "count".rjust(col_w)
    for ch in channels:
        header += " " + ch.rjust(col_w)
    lines.append(header)
    lines.append("-" * len(header))
    for row in sorted(rows, key=lambda r: r["timer"]):
        line = str(row["timer"]).ljust(name_w) + str(row["count"]).rjust(col_w)
        for ch in channels:
            line += " " + f"{float(row.get(ch, 0.0)):.8f}"[:col_w].rjust(col_w)
        lines.append(line)
    total = db.get("simulation/total").read_flat() if db.exists("simulation/total") else {}
    if total:
        lines.append("-" * len(header))
        line = "Total time for simulation".ljust(name_w) + "".rjust(col_w)
        for ch in channels:
            line += " " + f"{_channel_value(total, ch):.8f}"[:col_w].rjust(col_w)
        lines.append(line)
    if adapt is not None:
        lines.append("")
        lines.append(format_adapt_report(adapt))
    return "\n".join(lines)


def _tree_select(roots: list[TimerNode], prefix: str) -> list[TimerNode]:
    """Subtrees rooted at the outermost nodes matching ``prefix`` (whole
    path segments, like ``TimerDB.total_seconds``) — a nested scope such as
    ``bin/EVOL`` is found wherever it sits in the forest, not only at root."""
    if not prefix:
        return roots
    selected: list[TimerNode] = []

    def visit(node: TimerNode) -> None:
        if path_matches(node.name, prefix):
            selected.append(node)
            return  # keep the whole subtree; don't re-match descendants
        for child in node.children:
            visit(child)

    for root in roots:
        visit(root)
    return selected


def tree_rows(db: TimerDB | None = None, prefix: str = "") -> list[dict[str, object]]:
    """The timer forest as nested JSON-ready dicts.

    One dict per :class:`~repro.core.timers.TimerNode` — ``timer``, ``count``,
    ``inclusive_s``, ``exclusive_s``, ``children`` (recursively) — the payload
    the monitor serves at ``/tree``.  ``prefix`` selects the subtrees rooted
    at the outermost matching nodes, wherever they sit in the forest.
    """
    db = db if db is not None else timer_db()

    def convert(node: TimerNode) -> dict[str, object]:
        return {
            "timer": node.name,
            "count": node.count,
            "inclusive_s": node.inclusive,
            "exclusive_s": node.exclusive,
            "children": [convert(c) for c in node.children],
        }

    return [convert(root) for root in _tree_select(db.tree(), prefix)]


def format_tree_report(
    db: TimerDB | None = None,
    title: str = "Timer tree",
    prefix: str = "",
) -> str:
    """Render the hierarchical Fig.-2 report: one row per timer, indented by
    scope depth, with inclusive (subtree) and exclusive (self minus children)
    wall seconds — the stack-derived tree view of the flat table.  ``prefix``
    selects the subtrees rooted at the outermost matching nodes, wherever
    they sit in the forest."""
    db = db if db is not None else timer_db()
    roots = _tree_select(db.tree(), prefix)
    flat: list[tuple[int, TimerNode]] = []
    for root in roots:
        flat.extend(root.walk())
    name_w = max([2 * lvl + len(n.name) for lvl, n in flat] + [len("Timer")]) + 2
    col_w = 16
    header = (
        "Timer".ljust(name_w)
        + "count".rjust(8)
        + "inclusive_s".rjust(col_w)
        + "exclusive_s".rjust(col_w)
    )
    lines = [title, "=" * len(header), header, "-" * len(header)]
    for lvl, node in flat:
        lines.append(
            ("  " * lvl + node.name).ljust(name_w)
            + str(node.count).rjust(8)
            + f"{node.inclusive:.8f}"[:col_w].rjust(col_w)
            + f"{node.exclusive:.8f}"[:col_w].rjust(col_w)
        )
    if not flat:
        lines.append("(no timers)")
    return "\n".join(lines)


def bin_distribution(db: TimerDB | None = None) -> dict[str, float]:
    """Wall-time distribution over schedule bins (paper Fig. 1 right)."""
    db = db if db is not None else timer_db()
    out: dict[str, float] = {}
    for timer in db.timers():
        if timer.name.startswith("bin/"):
            out[timer.name[len("bin/"):]] = timer.seconds()
    return out


class TimerLogger:
    """Appends timer-DB snapshots as JSON lines for post-mortem review."""

    def __init__(self, path: str, db: TimerDB | None = None) -> None:
        self.path = path
        self._db = db if db is not None else timer_db()
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        # a writer killed mid-line (SIGKILL during log()) leaves a partial
        # trailing record; terminate it so this logger's first append starts
        # on a fresh line instead of fusing two records into garbage
        try:
            with open(self.path, "rb+") as f:
                f.seek(0, os.SEEK_END)
                if f.tell() > 0:
                    f.seek(-1, os.SEEK_END)
                    if f.read(1) != b"\n":
                        f.write(b"\n")
        except FileNotFoundError:
            pass

    def log(self, iteration: int, extra: Mapping[str, object] | None = None) -> None:
        record = {
            "t": time.time(),
            "iteration": iteration,
            "timers": self._db.snapshot(),
        }
        if extra:
            record["extra"] = dict(extra)
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")

    def read_all(self) -> list[dict]:
        """Parse every complete record; a torn line from a killed writer is
        skipped rather than raised (its step is re-logged on resume anyway)."""
        if not os.path.exists(self.path):
            return []
        out: list[dict] = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        return out
