"""Steerable parameters (paper Sec. 5: "Cactus applications are automatically
enabled with steerable parameters").

A process-global registry of typed parameters.  Parameters declared
``steerable=True`` may be changed while the run is live (e.g. from the
monitoring interface or a controller routine); non-steerable parameters are
frozen after the STARTUP bin runs.  Changes are validated and recorded with the
iteration at which they took effect, so the report can correlate behaviour
changes with steering events.
"""

from __future__ import annotations

import threading
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any


__all__ = ["Param", "ParamRegistry", "param_registry", "reset_param_registry"]


class ParamError(RuntimeError):
    pass


@dataclass
class Param:
    name: str
    value: Any
    steerable: bool = False
    doc: str = ""
    validator: Callable[[Any], bool] | None = None
    history: list[tuple[int, Any]] = field(default_factory=list)


class ParamRegistry:
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._params: dict[str, Param] = {}
        self._frozen = False

    def declare(
        self,
        name: str,
        default: Any,
        *,
        steerable: bool = False,
        doc: str = "",
        validator: Callable[[Any], bool] | None = None,
    ) -> Param:
        with self._lock:
            if name in self._params:
                return self._params[name]
            if validator is not None and not validator(default):
                raise ParamError(f"default for {name!r} fails validation")
            param = Param(name, default, steerable, doc, validator)
            self._params[name] = param
            return param

    def freeze(self) -> None:
        """Called after STARTUP: non-steerable params become immutable."""
        with self._lock:
            self._frozen = True

    def get(self, name: str) -> Any:
        with self._lock:
            if name not in self._params:
                raise ParamError(f"unknown parameter {name!r}")
            return self._params[name].value

    def set(self, name: str, value: Any, iteration: int = -1) -> None:
        with self._lock:
            if name not in self._params:
                raise ParamError(f"unknown parameter {name!r}")
            param = self._params[name]
            if self._frozen and not param.steerable:
                raise ParamError(f"parameter {name!r} is not steerable")
            if param.validator is not None and not param.validator(value):
                raise ParamError(f"value {value!r} fails validation for {name!r}")
            param.history.append((iteration, param.value))
            param.value = value

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._params)

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return {name: p.value for name, p in self._params.items()}

    def describe(self) -> list[dict]:
        with self._lock:
            return [
                {
                    "name": p.name,
                    "value": p.value,
                    "steerable": p.steerable,
                    "doc": p.doc,
                    "n_changes": len(p.history),
                }
                for p in self._params.values()
            ]


_REGISTRY = ParamRegistry()


def param_registry() -> ParamRegistry:
    return _REGISTRY


def reset_param_registry() -> ParamRegistry:
    global _REGISTRY
    _REGISTRY = ParamRegistry()
    return _REGISTRY
