"""Scheduler — Cactus-style schedule bins with automatic timers (paper Sec. 2).

Framework code is divided into modules ("thorns") that *schedule* routines into
named bins.  The scheduler controls execution order and is "a natural place to
put caliper points": every scheduled routine is wrapped in a timer named
``<BIN>/<thorn>::<routine>`` automatically, so any user or routine can obtain
timing statistics for any routine by querying the timer database — no explicit
instrumentation required.

Bins mirror the lifecycle of a training/serving run:

    STARTUP    — once, before the loop (mesh build, compile, restore)
    INITIAL    — once, after STARTUP (initial data / eval)
    PRESTEP    — every iteration, before the step (data fetch)
    EVOL       — every iteration: the jitted step itself
    ANALYSIS   — post-step analysis (eval, metrics); routines may be conditional
    CHECKPOINT — checkpoint decision + write (AdaptCheck lives here)
    OUTPUT     — reports, logs, monitoring
    SHUTDOWN   — once, after the loop (final checkpoint, final report)

Routines take a single :class:`RunState` argument and may mutate it.  Ordering
inside a bin respects ``before``/``after`` constraints (topological sort), like
Cactus schedule.ccl.
"""

from __future__ import annotations

from collections.abc import Callable, Sequence
from dataclasses import dataclass, field
from typing import Any

from .timers import TimerDB, timer_db


__all__ = ["BINS", "RunState", "ScheduledRoutine", "Scheduler", "schedule_bin_timer_name"]

BINS = (
    "STARTUP",
    "INITIAL",
    "PRESTEP",
    "EVOL",
    "ANALYSIS",
    "CHECKPOINT",
    "OUTPUT",
    "SHUTDOWN",
)

_LOOP_BINS = ("PRESTEP", "EVOL", "ANALYSIS", "CHECKPOINT", "OUTPUT")


@dataclass
class RunState:
    """Mutable state threaded through scheduled routines."""

    iteration: int = 0
    max_iterations: int = 0
    should_terminate: bool = False
    # free-form slots for thorns (params, opt state, data iterator, ...)
    slots: dict[str, Any] = field(default_factory=dict)

    def __getitem__(self, key: str) -> Any:
        return self.slots[key]

    def __setitem__(self, key: str, value: Any) -> None:
        self.slots[key] = value

    def get(self, key: str, default: Any = None) -> Any:
        return self.slots.get(key, default)


@dataclass
class ScheduledRoutine:
    name: str
    thorn: str
    fn: Callable[[RunState], None]
    bin: str
    every: int = 1  # run when iteration % every == 0
    when: Callable[[RunState], bool] | None = None
    before: Sequence[str] = ()
    after: Sequence[str] = ()

    @property
    def qualified(self) -> str:
        return f"{self.thorn}::{self.name}"


def schedule_bin_timer_name(bin: str) -> str:
    return f"bin/{bin}"


class ScheduleError(RuntimeError):
    pass


class Scheduler:
    """Executes scheduled routines bin by bin, wrapping everything in timers."""

    def __init__(self, db: TimerDB | None = None) -> None:
        self._db = db if db is not None else timer_db()
        self._routines: dict[str, list[ScheduledRoutine]] = {b: [] for b in BINS}
        self._sorted: dict[str, list[ScheduledRoutine] | None] = {b: None for b in BINS}
        # pre-resolved scope handle (repro.timing hot path): bin and routine
        # timers are real parent/child scopes — simulation/total encloses each
        # bin, each bin encloses its routines.  Dispatch resolves handles via
        # db.scope_handle, whose already-cached fast path is one dict read.
        self._total_scope = self._db.scope_handle("simulation/total")

    @property
    def db(self) -> TimerDB:
        return self._db

    # -- registration ---------------------------------------------------------
    def schedule(
        self,
        fn: Callable[[RunState], None],
        *,
        bin: str,
        thorn: str,
        name: str | None = None,
        every: int = 1,
        when: Callable[[RunState], bool] | None = None,
        before: Sequence[str] = (),
        after: Sequence[str] = (),
    ) -> ScheduledRoutine:
        if bin not in BINS:
            raise ScheduleError(f"unknown bin {bin!r}; bins are {BINS}")
        if every < 1:
            raise ScheduleError("every must be >= 1")
        routine = ScheduledRoutine(
            name=name or fn.__name__,
            thorn=thorn,
            fn=fn,
            bin=bin,
            every=every,
            when=when,
            before=tuple(before),
            after=tuple(after),
        )
        self._routines[bin].append(routine)
        self._sorted[bin] = None
        return routine

    def routines(self, bin: str) -> list[ScheduledRoutine]:
        return list(self._routines[bin])

    # -- ordering ---------------------------------------------------------------
    def _order(self, bin: str) -> list[ScheduledRoutine]:
        cached = self._sorted[bin]
        if cached is not None:
            return cached
        routines = self._routines[bin]
        by_name: dict[str, ScheduledRoutine] = {}
        for r in routines:
            by_name[r.name] = r
            by_name[r.qualified] = r
        # Build edges: a -> b means a must run before b.
        edges: dict[str, set] = {r.qualified: set() for r in routines}
        indeg: dict[str, int] = {r.qualified: 0 for r in routines}
        def add_edge(a: ScheduledRoutine, b: ScheduledRoutine) -> None:
            if b.qualified not in edges[a.qualified]:
                edges[a.qualified].add(b.qualified)
                indeg[b.qualified] += 1
        for r in routines:
            for other in r.before:
                if other in by_name:
                    add_edge(r, by_name[other])
            for other in r.after:
                if other in by_name:
                    add_edge(by_name[other], r)
        # Kahn, stable by registration order.
        order: list[ScheduledRoutine] = []
        ready = [r for r in routines if indeg[r.qualified] == 0]
        qual_to_routine = {r.qualified: r for r in routines}
        while ready:
            r = ready.pop(0)
            order.append(r)
            for succ in sorted(edges[r.qualified]):
                indeg[succ] -= 1
                if indeg[succ] == 0:
                    ready.append(qual_to_routine[succ])
        if len(order) != len(routines):
            raise ScheduleError(f"cyclic before/after constraints in bin {bin}")
        self._sorted[bin] = order
        return order

    # -- execution ---------------------------------------------------------------
    def _run_routine(self, routine: ScheduledRoutine, state: RunState) -> None:
        with self._db.scope_handle(f"{routine.bin}/{routine.qualified}"):
            routine.fn(state)

    def attach_control_loop(
        self,
        loop,
        *,
        bin: str = "ANALYSIS",
        every: int = 1,
        thorn: str = "adapt",
        name: str = "control_loop",
    ) -> ScheduledRoutine:
        """Drive a :class:`repro.adapt.ControlLoop` from the schedule.

        The loop is polled as an ordinary scheduled routine (duck-typed: any
        object with ``poll(step)``), so control decisions are caliper-timed
        like every other routine — the cost of adapting shows up in the same
        report as the cost of computing.  Default placement is the ANALYSIS
        bin: measurements from this iteration's EVOL are in the database, and
        decisions are ready before CHECKPOINT/OUTPUT consume them.
        """
        return self.schedule(
            lambda state: loop.poll(state.iteration),
            bin=bin,
            thorn=thorn,
            name=name,
            every=every,
        )

    def run_bin(self, bin: str, state: RunState) -> None:
        with self._db.scope_handle(schedule_bin_timer_name(bin)):
            for routine in self._order(bin):
                if bin in _LOOP_BINS:
                    if routine.every > 1 and state.iteration % routine.every != 0:
                        continue
                if routine.when is not None and not routine.when(state):
                    continue
                self._run_routine(routine, state)

    def run(self, state: RunState) -> RunState:
        """Full lifecycle: STARTUP, INITIAL, loop(PRESTEP..OUTPUT), SHUTDOWN."""
        with self._total_scope:
            self.run_bin("STARTUP", state)
            self.run_bin("INITIAL", state)
            while not state.should_terminate and state.iteration < state.max_iterations:
                for bin in _LOOP_BINS:
                    self.run_bin(bin, state)
                    if state.should_terminate:
                        break
                state.iteration += 1
            self.run_bin("SHUTDOWN", state)
        return state

    def total_seconds(self) -> float:
        return self._db.get("simulation/total").seconds()
