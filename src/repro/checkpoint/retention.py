"""Retention policy: ``keep_last_n`` + ``keep_every_k`` with safe GC.

The policy is pure arithmetic over step numbers (:meth:`RetentionPolicy.keeps`
/ :meth:`RetentionPolicy.doomed`) so it is testable without a filesystem; the
manager layers the one safety invariant that must never be policy-tunable on
top: **GC can never delete the newest valid checkpoint**, even when the
policy would — if the newest ``keep_last_n`` checkpoints all turn out corrupt,
the last-known-good one stays on disk no matter how old it is.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["RetentionPolicy"]


@dataclass(frozen=True)
class RetentionPolicy:
    """Which checkpoint steps survive garbage collection.

    ``keep_last_n``: the newest N checkpoints always survive (0 keeps none on
    recency grounds alone).  ``keep_every_k``: additionally keep every
    checkpoint whose step is a multiple of K — the long-horizon archive rungs
    (0 disables).  A checkpoint survives if *either* rule keeps it.
    """

    keep_last_n: int = 3
    keep_every_k: int = 0

    def __post_init__(self) -> None:
        if self.keep_last_n < 0:
            raise ValueError(f"keep_last_n must be >= 0, got {self.keep_last_n}")
        if self.keep_every_k < 0:
            raise ValueError(f"keep_every_k must be >= 0, got {self.keep_every_k}")

    def keeps(self, steps: list[int]) -> set[int]:
        """The subset of ``steps`` the policy retains."""
        ordered = sorted(set(steps))
        kept = set(
            ordered[max(len(ordered) - self.keep_last_n, 0):] if self.keep_last_n else ()
        )
        if self.keep_every_k:
            kept.update(s for s in ordered if s % self.keep_every_k == 0)
        return kept

    def doomed(self, steps: list[int]) -> list[int]:
        """The steps GC may delete (ascending); the caller must still protect
        the newest *valid* checkpoint regardless of what this returns."""
        kept = self.keeps(steps)
        return [s for s in sorted(set(steps)) if s not in kept]

    def summary(self) -> dict[str, int]:
        return {"keep_last_n": self.keep_last_n, "keep_every_k": self.keep_every_k}
