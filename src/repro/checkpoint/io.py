"""Sharded, atomic checkpoint serialization.

Layout: one directory per checkpoint (``step_00000123/``) containing one .npy
per leaf plus ``manifest.json`` (tree skeleton, shapes, dtypes, CRC32 per leaf,
user metadata).  Writes go to a ``.tmp`` sibling and are published with an
atomic ``os.replace`` after a COMMIT marker — a crash mid-write can never leave
a readable-but-corrupt checkpoint.  CRCs are verified at load; corrupt or
uncommitted directories are skipped by the manager.

Restart elasticity: leaves are stored as *global* arrays (this container is a
single host).  On a multi-host deployment each host would write its address-
able shards and the manifest would carry the index map — the load path already
re-shards via ``jax.device_put(..., sharding)``, so restoring onto a different
mesh works.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "checkpoint_nbytes"]

_MANIFEST = "manifest.json"
_COMMIT = "COMMITTED"

#: dtypes that np.save/np.load roundtrip natively
_NUMPY_NATIVE = frozenset(
    np.dtype(t)
    for t in ("bool", "int8", "int16", "int32", "int64", "uint8", "uint16",
              "uint32", "uint64", "float16", "float32", "float64",
              "complex64", "complex128")
)


def _skeleton(tree, leaves: list) -> Any:
    if isinstance(tree, dict):
        return {"__t": "dict", "items": {k: _skeleton(v, leaves) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__t": "tuple", "items": [_skeleton(v, leaves) for v in tree]}
    if isinstance(tree, list):
        return {"__t": "list", "items": [_skeleton(v, leaves) for v in tree]}
    if tree is None:
        return {"__t": "none"}
    idx = len(leaves)
    leaves.append(tree)
    return {"__t": "leaf", "idx": idx}


def _rebuild(skel, leaves):
    t = skel["__t"]
    if t == "dict":
        return {k: _rebuild(v, leaves) for k, v in skel["items"].items()}
    if t == "tuple":
        return tuple(_rebuild(v, leaves) for v in skel["items"])
    if t == "list":
        return [_rebuild(v, leaves) for v in skel["items"]]
    if t == "none":
        return None
    return leaves[skel["idx"]]


def checkpoint_nbytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size")
    )


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    metadata: dict[str, Any] | None = None,
    fsync: bool = False,
) -> tuple[str, int]:
    """Write atomically; returns (final_path, bytes_written).

    ``tree`` leaves must already be host arrays (the manager snapshots devices
    before calling, so device transfer is not hidden inside the write path).
    """
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        import shutil

        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves: list = []
    skel = _skeleton(tree, leaves)
    files = []
    total = 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype not in _NUMPY_NATIVE:
            # ml_dtypes (bfloat16, fp8) don't roundtrip through np.save on
            # loaders without the dtype registered — store a same-width
            # unsigned view and reinterpret at load.
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        fname = f"leaf_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        np.save(path, arr)
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        files.append(
            {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical_dtype,
                "stored_dtype": str(arr.dtype),
                "crc32": crc,
            }
        )
        total += arr.nbytes
        if fsync:
            with open(path, "rb") as f:
                os.fsync(f.fileno())
    manifest = {
        "step": step,
        "skeleton": skel,
        "leaves": files,
        "metadata": metadata or {},
        "format_version": 1,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final, total


class CheckpointCorrupt(RuntimeError):
    pass


def load_checkpoint(
    path: str, shardings: Any | None = None, verify: bool = True
) -> tuple[int, Any, dict[str, Any]]:
    """Load one checkpoint directory. Returns (step, tree, metadata)."""
    if not os.path.exists(os.path.join(path, _COMMIT)):
        raise CheckpointCorrupt(f"{path}: missing commit marker")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        fpath = os.path.join(path, entry["file"])
        if verify:
            with open(fpath, "rb") as f:
                if zlib.crc32(f.read()) != entry["crc32"]:
                    raise CheckpointCorrupt(f"{fpath}: CRC mismatch")
        arr = np.load(fpath)
        if entry.get("stored_dtype", entry["dtype"]) != entry["dtype"]:
            import ml_dtypes  # noqa: F401 - registers bf16/fp8 numpy dtypes

            arr = arr.view(np.dtype(entry["dtype"]))
        leaves.append(arr)
    tree = _rebuild(manifest["skeleton"], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            tree,
            shardings,
        )
    return manifest["step"], tree, manifest.get("metadata", {})
