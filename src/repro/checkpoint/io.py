"""Sharded, atomic checkpoint serialization with verifiable manifests.

Layout: one directory per checkpoint (``step_00000123/``) containing one .npy
per leaf plus ``manifest.json`` (tree skeleton, shapes, dtypes, per-leaf
sha256 + CRC32 + byte size, user metadata).  Writes go to a ``.tmp`` sibling
and are published with an atomic ``os.replace`` after a COMMIT marker — a
crash mid-write can never leave a readable-but-corrupt checkpoint, only a
stale ``.tmp`` the resume scan quarantines.

Integrity is hashed **during** the write: every chunk numpy streams to disk
passes through a tee that updates sha256/CRC32 as it goes, so a multi-GB leaf
is never re-read (or held twice) just to fingerprint it.  The read side
mirrors that: :func:`validate_checkpoint` re-hashes leaf files in fixed-size
chunks — without ever deserializing an array — and raises
:class:`CheckpointCorrupt` with a machine-readable ``reason`` (the string the
quarantine layer writes into the corrupt checkpoint's reason file).

Restart elasticity: leaves are stored as *global* arrays (this container is a
single host).  On a multi-host deployment each host would write its address-
able shards and the manifest would carry the index map — the load path already
re-shards via ``jax.device_put(..., sharding)``, so restoring onto a different
mesh works.
"""

from __future__ import annotations

import hashlib
import json
import os
import zlib
from typing import Any

import jax
import numpy as np

__all__ = [
    "CheckpointCorrupt",
    "checkpoint_nbytes",
    "load_checkpoint",
    "save_checkpoint",
    "validate_checkpoint",
]

_MANIFEST = "manifest.json"
_COMMIT = "COMMITTED"
#: chunk size for streamed verification reads (bounded peak memory per leaf)
_HASH_CHUNK = 1 << 20

#: dtypes that np.save/np.load roundtrip natively
_NUMPY_NATIVE = frozenset(
    np.dtype(t)
    for t in ("bool", "int8", "int16", "int32", "int64", "uint8", "uint16",
              "uint32", "uint64", "float16", "float32", "float64",
              "complex64", "complex128")
)


def _skeleton(tree, leaves: list) -> Any:
    if isinstance(tree, dict):
        return {"__t": "dict", "items": {k: _skeleton(v, leaves) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__t": "tuple", "items": [_skeleton(v, leaves) for v in tree]}
    if isinstance(tree, list):
        return {"__t": "list", "items": [_skeleton(v, leaves) for v in tree]}
    if tree is None:
        return {"__t": "none"}
    idx = len(leaves)
    leaves.append(tree)
    return {"__t": "leaf", "idx": idx}


def _rebuild(skel, leaves):
    t = skel["__t"]
    if t == "dict":
        return {k: _rebuild(v, leaves) for k, v in skel["items"].items()}
    if t == "tuple":
        return tuple(_rebuild(v, leaves) for v in skel["items"])
    if t == "list":
        return [_rebuild(v, leaves) for v in skel["items"]]
    if t == "none":
        return None
    return leaves[skel["idx"]]


def checkpoint_nbytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size")
    )


class _HashingWriter:
    """File-object tee: hashes every chunk ``np.save`` writes, as it writes.

    Not a real file object on purpose — numpy's ``isfileobj`` check fails for
    it, so ``write_array`` takes the buffered path and streams the array in
    bounded chunks through :meth:`write` instead of ``tofile``; sha256/CRC32
    therefore cover exactly the bytes on disk with no second read pass.
    """

    __slots__ = ("_f", "sha256", "crc32", "nbytes")

    def __init__(self, f) -> None:
        self._f = f
        self.sha256 = hashlib.sha256()
        self.crc32 = 0
        self.nbytes = 0

    def write(self, data) -> int:
        view = memoryview(data) if not isinstance(data, (bytes, bytearray)) else data
        self.sha256.update(view)
        self.crc32 = zlib.crc32(view, self.crc32)
        self.nbytes += len(view)
        return self._f.write(data)


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed validation.  ``reason`` is the machine-readable
    category (``missing_commit``, ``missing_manifest``, ``manifest_unreadable``,
    ``missing_leaf``, ``leaf_size_mismatch``, ``leaf_hash_mismatch``) used for
    quarantine reason files and the ``ckpt_validation_failures`` counter."""

    def __init__(self, message: str, reason: str = "corrupt") -> None:
        super().__init__(message)
        self.reason = reason


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    metadata: dict[str, Any] | None = None,
    fsync: bool = False,
) -> tuple[str, int]:
    """Write atomically; returns (final_path, bytes_written).

    ``tree`` leaves must already be host arrays (the manager snapshots devices
    before calling, so device transfer is not hidden inside the write path).
    """
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        import shutil

        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves: list = []
    skel = _skeleton(tree, leaves)
    files = []
    total = 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype not in _NUMPY_NATIVE:
            # ml_dtypes (bfloat16, fp8) don't roundtrip through np.save on
            # loaders without the dtype registered — store a same-width
            # unsigned view and reinterpret at load.
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        fname = f"leaf_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        with open(path, "wb") as f:
            tee = _HashingWriter(f)
            np.save(tee, arr)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        files.append(
            {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical_dtype,
                "stored_dtype": str(arr.dtype),
                "nbytes": tee.nbytes,
                "crc32": tee.crc32,
                "sha256": tee.sha256.hexdigest(),
            }
        )
        total += arr.nbytes
    manifest = {
        "step": step,
        "skeleton": skel,
        "leaves": files,
        "metadata": metadata or {},
        "format_version": 2,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final, total


def _stream_digests(path: str) -> tuple[str, int, int]:
    """(sha256 hex, crc32, nbytes) of a file, read in bounded chunks."""
    sha = hashlib.sha256()
    crc = 0
    nbytes = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(_HASH_CHUNK)
            if not chunk:
                break
            sha.update(chunk)
            crc = zlib.crc32(chunk, crc)
            nbytes += len(chunk)
    return sha.hexdigest(), crc, nbytes


def validate_checkpoint(path: str) -> dict[str, Any]:
    """Structurally and cryptographically validate one checkpoint directory.

    Returns the parsed manifest on success.  Raises :class:`CheckpointCorrupt`
    (with ``reason`` set) the moment any check fails — commit marker, manifest
    presence/parse, leaf presence, byte size, then content hash.  No array is
    ever deserialized: a corrupt checkpoint is rejected *before* anything is
    loaded, and the streamed re-hash keeps peak memory at one chunk.
    """
    if not os.path.isdir(path):
        raise CheckpointCorrupt(f"{path}: not a checkpoint directory", "missing_directory")
    if not os.path.exists(os.path.join(path, _COMMIT)):
        raise CheckpointCorrupt(f"{path}: missing commit marker", "missing_commit")
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        raise CheckpointCorrupt(f"{path}: missing manifest", "missing_manifest")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        entries = manifest["leaves"]
        _ = manifest["skeleton"], manifest["step"]
    except (json.JSONDecodeError, KeyError, TypeError, UnicodeDecodeError) as exc:
        raise CheckpointCorrupt(
            f"{mpath}: unreadable manifest ({exc})", "manifest_unreadable"
        ) from exc
    for entry in entries:
        fpath = os.path.join(path, entry["file"])
        if not os.path.exists(fpath):
            raise CheckpointCorrupt(f"{fpath}: missing leaf file", "missing_leaf")
        expected_nbytes = entry.get("nbytes")
        if expected_nbytes is not None and os.path.getsize(fpath) != expected_nbytes:
            raise CheckpointCorrupt(
                f"{fpath}: size {os.path.getsize(fpath)} != manifest {expected_nbytes}",
                "leaf_size_mismatch",
            )
        sha, crc, _n = _stream_digests(fpath)
        expected_sha = entry.get("sha256")
        if expected_sha is not None:
            if sha != expected_sha:
                raise CheckpointCorrupt(f"{fpath}: sha256 mismatch", "leaf_hash_mismatch")
        elif crc != entry["crc32"]:  # format_version 1 fallback
            raise CheckpointCorrupt(f"{fpath}: CRC mismatch", "leaf_hash_mismatch")
    return manifest


def load_checkpoint(
    path: str, shardings: Any | None = None, verify: bool = True
) -> tuple[int, Any, dict[str, Any]]:
    """Load one checkpoint directory. Returns (step, tree, metadata).

    With ``verify=True`` (default) the directory passes the full
    :func:`validate_checkpoint` gate *before* any ``np.load`` — corrupt data
    is never deserialized.  ``verify=False`` skips re-hashing for callers that
    just validated (e.g. the manager's resume path).
    """
    if verify:
        manifest = validate_checkpoint(path)
    else:
        if not os.path.exists(os.path.join(path, _COMMIT)):
            raise CheckpointCorrupt(f"{path}: missing commit marker", "missing_commit")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        fpath = os.path.join(path, entry["file"])
        arr = np.load(fpath)
        if entry.get("stored_dtype", entry["dtype"]) != entry["dtype"]:
            import ml_dtypes  # noqa: F401 - registers bf16/fp8 numpy dtypes

            arr = arr.view(np.dtype(entry["dtype"]))
        leaves.append(arr)
    tree = _rebuild(manifest["skeleton"], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            tree,
            shardings,
        )
    return manifest["step"], tree, manifest.get("metadata", {})
