from .io import CheckpointCorrupt, load_checkpoint, save_checkpoint, validate_checkpoint
from .manager import CheckpointManager
from .resume import CheckpointRecord, ResumePlan, plan_resume, scan_checkpoints
from .retention import RetentionPolicy

__all__ = [
    "CheckpointCorrupt",
    "CheckpointManager",
    "CheckpointRecord",
    "ResumePlan",
    "RetentionPolicy",
    "load_checkpoint",
    "plan_resume",
    "save_checkpoint",
    "scan_checkpoints",
    "validate_checkpoint",
]
