from .io import load_checkpoint, save_checkpoint
from .manager import CheckpointManager

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]
