"""Resume planning: scan → validate → quarantine → select.

This replaces the silent try-loop ``restore_latest`` used to carry.  A
:func:`plan_resume` pass walks every ``step_XXXXXXXX`` directory (and every
stale ``.tmp`` a killed writer left behind), validates each without loading
it, and produces a :class:`ResumePlan`:

* **valid** checkpoints, newest first — ``selected`` is the newest
  (latest-valid policy) and the remainder are the last-known-good fallbacks
  the loader walks if the selected one fails between validation and load;
* **corrupt** entries are moved into a ``corrupt/`` quarantine next to the
  live checkpoints, each with a ``REASON.txt`` naming the validation failure
  — nothing is deleted, so an operator can inspect (or hand-repair) the
  evidence, and a corrupt directory can never be scanned or loaded again;
* every quarantine bumps the ``ckpt_validation_failures`` and
  ``ckpt_corrupt_detected`` counters and a ``CHECKPOINT/quarantine::<reason>``
  count row, so corruption is visible in the timing report rather than
  silently skipped; a successful selection bumps ``ckpt_resume_selected``.

The scan never deserializes an array: validation is structural + streamed
hashing (:func:`repro.checkpoint.io.validate_checkpoint`).
"""

from __future__ import annotations

import os
import re
import shutil
from dataclasses import dataclass, field
from typing import Any

from ..core.clocks import counter_cell
from ..core.timers import timer_db
from .io import CheckpointCorrupt, validate_checkpoint

__all__ = [
    "CheckpointRecord",
    "ResumePlan",
    "list_quarantined",
    "plan_resume",
    "quarantine_checkpoint",
    "scan_checkpoints",
]

_STEP_RE = re.compile(r"^step_(\d{8})$")
_TMP_RE = re.compile(r"^step_(\d{8})\.tmp$")
_QUARANTINE_DIR = "corrupt"
_REASON_FILE = "REASON.txt"


def _bump(name: str, value: float = 1.0) -> None:
    """Lock-free counter bump, exported so reports can render the channel."""
    from ..timing.session import export_counter_channel

    export_counter_channel(name)
    counter_cell(name)(value)


def _count_row(name: str) -> None:
    """Increment a timer-DB count row (renders in the flat Fig.-2 report)."""
    db = timer_db()
    db.scope_handle(name).timer.count += 1


@dataclass(frozen=True)
class CheckpointRecord:
    """One scanned checkpoint directory and its validation verdict."""

    step: int
    path: str
    status: str  # "valid" | "corrupt" | "stale_tmp"
    reason: str | None = None

    def summary(self) -> dict[str, Any]:
        return {
            "step": self.step,
            "path": self.path,
            "status": self.status,
            "reason": self.reason,
        }


@dataclass
class ResumePlan:
    """The outcome of one resume scan over a checkpoint directory.

    ``selected`` is the newest valid checkpoint (``None`` when nothing valid
    survives); ``records`` holds every scanned entry newest-first and
    ``quarantined`` the subset that was moved into ``corrupt/`` this scan.
    """

    directory: str
    records: list[CheckpointRecord] = field(default_factory=list)
    quarantined: list[CheckpointRecord] = field(default_factory=list)

    @property
    def valid(self) -> list[CheckpointRecord]:
        """Valid checkpoints, newest first: ``valid[0]`` is the latest-valid
        selection, the rest are last-known-good fallbacks in order."""
        return [r for r in self.records if r.status == "valid"]

    @property
    def corrupt(self) -> list[CheckpointRecord]:
        return [r for r in self.records if r.status != "valid"]

    @property
    def selected(self) -> CheckpointRecord | None:
        valid = self.valid
        return valid[0] if valid else None

    def summary(self) -> dict[str, Any]:
        sel = self.selected
        return {
            "directory": self.directory,
            "selected_step": sel.step if sel else None,
            "n_valid": len(self.valid),
            "n_corrupt": len(self.corrupt),
            "n_quarantined": len(self.quarantined),
            "quarantined": [r.summary() for r in self.quarantined],
        }


def scan_checkpoints(directory: str, validate: bool = True) -> list[CheckpointRecord]:
    """Scan ``directory`` for checkpoints and stale writer leftovers.

    Returns records newest-first.  With ``validate=True`` each committed
    directory goes through the full (load-free) validation gate; stale
    ``.tmp`` directories — the debris of a writer killed mid-write — are
    always recorded as ``stale_tmp``.
    """
    if not os.path.isdir(directory):
        return []
    records: list[CheckpointRecord] = []
    for name in sorted(os.listdir(directory), reverse=True):
        full = os.path.join(directory, name)
        m = _TMP_RE.match(name)
        if m is not None:
            records.append(
                CheckpointRecord(int(m.group(1)), full, "stale_tmp", "stale_tmp")
            )
            continue
        m = _STEP_RE.match(name)
        if m is None:
            continue
        step = int(m.group(1))
        if not validate:
            records.append(CheckpointRecord(step, full, "valid"))
            continue
        try:
            validate_checkpoint(full)
        except CheckpointCorrupt as exc:
            records.append(CheckpointRecord(step, full, "corrupt", exc.reason))
        else:
            records.append(CheckpointRecord(step, full, "valid"))
    records.sort(key=lambda r: (r.step, r.path), reverse=True)
    return records


def quarantine_checkpoint(path: str, reason: str, root: str | None = None) -> str:
    """Move a corrupt checkpoint into ``<root>/corrupt/`` with a reason file.

    Returns the quarantine destination.  The move is a rename when possible
    (same filesystem — atomic, no partial state); the reason file records the
    validation failure for post-mortems.  A name collision (same checkpoint
    corrupted twice across restarts) gets a numeric suffix rather than
    overwriting earlier evidence.
    """
    root = root if root is not None else os.path.dirname(os.path.abspath(path))
    qdir = os.path.join(root, _QUARANTINE_DIR)
    os.makedirs(qdir, exist_ok=True)
    base = os.path.basename(path.rstrip(os.sep))
    dest = os.path.join(qdir, base)
    n = 1
    while os.path.exists(dest):
        dest = os.path.join(qdir, f"{base}.{n}")
        n += 1
    shutil.move(path, dest)
    with open(os.path.join(dest, _REASON_FILE), "w") as f:
        f.write(reason + "\n")
    return dest


def list_quarantined(directory: str) -> list[dict[str, str]]:
    """Quarantined entries under ``directory/corrupt/`` with their reasons."""
    qdir = os.path.join(directory, _QUARANTINE_DIR)
    if not os.path.isdir(qdir):
        return []
    out = []
    for name in sorted(os.listdir(qdir)):
        full = os.path.join(qdir, name)
        if not os.path.isdir(full):
            continue
        reason_path = os.path.join(full, _REASON_FILE)
        reason = ""
        if os.path.exists(reason_path):
            with open(reason_path) as f:
                reason = f.read().strip()
        out.append({"name": name, "path": full, "reason": reason})
    return out


def plan_resume(directory: str, quarantine: bool = True) -> ResumePlan:
    """Scan, quarantine corruption, and select the checkpoint to resume from.

    The latest-valid policy: the newest checkpoint that passes validation is
    selected; everything that fails is quarantined (when ``quarantine=True``)
    with a reason file, counted on ``ckpt_validation_failures`` /
    ``ckpt_corrupt_detected``, and surfaced as a
    ``CHECKPOINT/quarantine::<reason>`` row in the timing report.
    """
    records = scan_checkpoints(directory, validate=True)
    plan = ResumePlan(directory=directory, records=records)
    for record in records:
        if record.status == "valid":
            continue
        _bump("ckpt_validation_failures")
        _bump("ckpt_corrupt_detected")
        _count_row(f"CHECKPOINT/quarantine::{record.reason}")
        if quarantine:
            quarantine_checkpoint(record.path, record.reason or record.status,
                                  root=directory)
            plan.quarantined.append(record)
    if plan.selected is not None:
        _bump("ckpt_resume_selected")
    return plan
