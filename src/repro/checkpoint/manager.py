"""Checkpoint manager: async writes, retention, emergency save, restore-latest.

Timing integration (the paper's subject): ``save`` splits into a *blocking*
phase — device→host snapshot + submission, the part that steals wall time from
compute and is what AdaptCheck bounds — and an *async* phase on a writer
thread.  The blocking seconds and written bytes are reported to the caller and
pushed onto the ``io`` counter channels so every timer window can see I/O
traffic.  ``synchronous=True`` reproduces the paper's blocking checkpointing
(used as the paper-faithful baseline in benchmarks).
"""

from __future__ import annotations

import os
import re
import signal
import threading
import time
from collections.abc import Callable
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from ..timing import counter
from .io import CheckpointCorrupt, checkpoint_nbytes, load_checkpoint, save_checkpoint


# channel cells resolved once through the timing facade (lock-free C-level
# increment on the write path); absolute: the `io` CounterClock exports them
_BUMP_IO_BYTES = counter("io_bytes", absolute=True)
_BUMP_IO_OPS = counter("io_ops", absolute=True)

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep_n: int = 3,
        synchronous: bool = False,
        fsync: bool = False,
        delay_s: float = 0.0,
        delay_s_per_mb: float = 0.0,
    ) -> None:
        """``delay_s`` (+ ``delay_s_per_mb`` × payload) injects artificial write
        latency (experiments: emulate a slow/contended filesystem and
        size-proportional write cost, as in the paper's AMR scenario where
        checkpoint data grows O(L))."""
        self.directory = directory
        self.keep_n = keep_n
        self.synchronous = synchronous
        self.fsync = fsync
        self.delay_s = delay_s
        self.delay_s_per_mb = delay_s_per_mb
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")
        self._pending: Future | None = None
        self._lock = threading.Lock()
        self.n_saves = 0
        self.total_blocking_seconds = 0.0
        self.total_bytes = 0

    # -- save ------------------------------------------------------------------
    def _write(self, step: int, host_tree, metadata) -> tuple[str, int]:
        if self.delay_s or self.delay_s_per_mb:
            nbytes = checkpoint_nbytes(host_tree)
            time.sleep(self.delay_s + self.delay_s_per_mb * nbytes / 1e6)
        path, nbytes = save_checkpoint(
            self.directory, step, host_tree, metadata, fsync=self.fsync
        )
        _BUMP_IO_BYTES(float(nbytes))
        _BUMP_IO_OPS(1.0)
        self._gc()
        return path, nbytes

    def save(
        self, step: int, tree: Any, metadata: dict[str, Any] | None = None
    ) -> dict[str, float]:
        """Snapshot + write. Returns stats incl. blocking seconds and bytes."""
        t0 = time.monotonic()
        self.wait()  # never queue more than one outstanding write
        host_tree = jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") or hasattr(x, "devices") else x,
            tree,
        )
        nbytes = checkpoint_nbytes(host_tree)
        if self.synchronous:
            self._write(step, host_tree, metadata)
            blocking = time.monotonic() - t0
        else:
            self._pending = self._pool.submit(self._write, step, host_tree, metadata)
            blocking = time.monotonic() - t0
        with self._lock:
            self.n_saves += 1
            self.total_blocking_seconds += blocking
            self.total_bytes += nbytes
        return {"blocking_seconds": blocking, "nbytes": float(nbytes), "step": float(step)}

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ---------------------------------------------------------------
    def checkpoints(self) -> list[tuple[int, str]]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.directory, name)))
        return sorted(out)

    def restore_latest(
        self, shardings: Any | None = None
    ) -> tuple[int, Any, dict[str, Any]] | None:
        """Latest valid checkpoint (corrupt/uncommitted ones are skipped)."""
        for _step, path in reversed(self.checkpoints()):
            try:
                return load_checkpoint(path, shardings=shardings)
            except (CheckpointCorrupt, FileNotFoundError, ValueError):
                continue
        return None

    # -- retention / fault hooks -------------------------------------------------
    def _gc(self) -> None:
        ckpts = self.checkpoints()
        for _, path in ckpts[: max(len(ckpts) - self.keep_n, 0)]:
            import shutil

            shutil.rmtree(path, ignore_errors=True)

    def install_sigterm_handler(self, state_fn: Callable[[], tuple[int, Any]]) -> None:
        """Emergency checkpoint on SIGTERM (pre-emption / queue kill)."""

        def handler(signum, frame):  # pragma: no cover - signal path
            step, tree = state_fn()
            self.wait()
            host_tree = jax.tree.map(jax.device_get, tree)
            self._write(step, host_tree, {"emergency": True})

        signal.signal(signal.SIGTERM, handler)

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
