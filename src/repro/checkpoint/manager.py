"""Checkpoint manager: async writes, retention, emergency save, resume planning.

Timing integration (the paper's subject): ``save`` splits into a *blocking*
phase — device→host snapshot + submission, the part that steals wall time from
compute and is what AdaptCheck bounds — and an *async* phase on a writer
thread.  The blocking seconds and written bytes are reported to the caller and
pushed onto the ``io`` counter channels so every timer window can see I/O
traffic.  ``synchronous=True`` reproduces the paper's blocking checkpointing
(used as the paper-faithful baseline in benchmarks).

Fault tolerance is structural, not best-effort:

* restores go through a :class:`~repro.checkpoint.resume.ResumePlan` — every
  on-disk checkpoint is validated (load-free streamed hashing), corrupt ones
  are quarantined into ``corrupt/`` with a reason file and counted, and the
  newest valid one is selected (latest-valid with last-known-good fallback);
* retention is a :class:`~repro.checkpoint.retention.RetentionPolicy`
  (``keep_last_n`` + ``keep_every_k``) whose GC can **never** delete the
  newest valid checkpoint, even when every newer directory is corrupt;
* directory mutations (write, GC, quarantine, scan) serialize on one
  filesystem lock, so the async writer's GC cannot race a concurrent
  ``checkpoints()`` / ``restore_latest`` on the caller thread;
* :meth:`install_sigterm_handler` performs a *deadline-bounded* emergency
  save (preemption notice → durable checkpoint before the platform's grace
  period expires) and chains any previously installed handler instead of
  clobbering it.
"""

from __future__ import annotations

import os
import signal
import threading
import time
from collections.abc import Callable
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any

import jax

from ..timing import counter
from .io import checkpoint_nbytes, load_checkpoint, save_checkpoint
from .resume import ResumePlan, list_quarantined, plan_resume, quarantine_checkpoint, scan_checkpoints
from .retention import RetentionPolicy

# channel cells resolved once through the timing facade (lock-free C-level
# increment on the write path); absolute: the `io` CounterClock exports them
_BUMP_IO_BYTES = counter("io_bytes", absolute=True)
_BUMP_IO_OPS = counter("io_ops", absolute=True)

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep_n: int = 3,
        synchronous: bool = False,
        fsync: bool = False,
        delay_s: float = 0.0,
        delay_s_per_mb: float = 0.0,
        keep_every_k: int = 0,
        retention: RetentionPolicy | None = None,
    ) -> None:
        """``delay_s`` (+ ``delay_s_per_mb`` × payload) injects artificial write
        latency (experiments: emulate a slow/contended filesystem and
        size-proportional write cost, as in the paper's AMR scenario where
        checkpoint data grows O(L)).  ``retention`` overrides the
        ``keep_n``/``keep_every_k`` sugar with an explicit policy."""
        self.directory = directory
        self.retention = (
            retention
            if retention is not None
            else RetentionPolicy(keep_last_n=keep_n, keep_every_k=keep_every_k)
        )
        self.synchronous = synchronous
        self.fsync = fsync
        self.delay_s = delay_s
        self.delay_s_per_mb = delay_s_per_mb
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")
        self._pending: Future | None = None
        #: guards manager state: _pending and the save statistics
        self._lock = threading.Lock()
        #: guards directory mutations/listings: the async writer runs GC while
        #: the caller thread may be scanning (checkpoints / restore_latest)
        self._fs_lock = threading.Lock()
        self.n_saves = 0
        self.total_blocking_seconds = 0.0
        self.total_bytes = 0
        self.last_resume_plan: ResumePlan | None = None

    @property
    def keep_n(self) -> int:  # back-compat alias for the retention knob
        return self.retention.keep_last_n

    # -- save ------------------------------------------------------------------
    def _write(self, step: int, host_tree, metadata) -> tuple[str, int]:
        if self.delay_s or self.delay_s_per_mb:
            nbytes = checkpoint_nbytes(host_tree)
            time.sleep(self.delay_s + self.delay_s_per_mb * nbytes / 1e6)
        with self._fs_lock:
            path, nbytes = save_checkpoint(
                self.directory, step, host_tree, metadata, fsync=self.fsync
            )
        _BUMP_IO_BYTES(float(nbytes))
        _BUMP_IO_OPS(1.0)
        self.gc()
        return path, nbytes

    def save(
        self, step: int, tree: Any, metadata: dict[str, Any] | None = None
    ) -> dict[str, float]:
        """Snapshot + write. Returns stats incl. blocking seconds and bytes."""
        t0 = time.monotonic()
        self.wait()  # never queue more than one outstanding write
        host_tree = jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") or hasattr(x, "devices") else x,
            tree,
        )
        nbytes = checkpoint_nbytes(host_tree)
        if self.synchronous:
            self._write(step, host_tree, metadata)
            blocking = time.monotonic() - t0
        else:
            future = self._pool.submit(self._write, step, host_tree, metadata)
            blocking = time.monotonic() - t0
            with self._lock:
                self._pending = future
        with self._lock:
            self.n_saves += 1
            self.total_blocking_seconds += blocking
            self.total_bytes += nbytes
        return {"blocking_seconds": blocking, "nbytes": float(nbytes), "step": float(step)}

    def wait(self, timeout: float | None = None) -> None:
        """Block until the outstanding async write (if any) is durable."""
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is not None:
            try:
                pending.result(timeout=timeout)
            except (_FuturesTimeout, TimeoutError) as exc:
                # (futures.TimeoutError is a distinct class before 3.11)
                # still in flight: put it back so a later wait can finish it
                with self._lock:
                    if self._pending is None:
                        self._pending = pending
                raise TimeoutError(str(exc) or "checkpoint write still in flight") from exc

    # -- restore ---------------------------------------------------------------
    def checkpoints(self) -> list[tuple[int, str]]:
        """Committed checkpoint directories (no validation), oldest first."""
        with self._fs_lock:
            records = scan_checkpoints(self.directory, validate=False)
        return sorted((r.step, r.path) for r in records if r.status == "valid")

    def resume_plan(self, quarantine: bool = True) -> ResumePlan:
        """Scan + validate + quarantine; the full resume picture without
        loading anything.  Stored on :attr:`last_resume_plan`."""
        with self._fs_lock:
            plan = plan_resume(self.directory, quarantine=quarantine)
        self.last_resume_plan = plan
        return plan

    def restore_latest(
        self, shardings: Any | None = None
    ) -> tuple[int, Any, dict[str, Any]] | None:
        """Load the newest valid checkpoint per the :class:`ResumePlan`.

        Corrupt directories are quarantined with a reason file and counted
        (``ckpt_validation_failures``) — never silently skipped.  If the
        selected checkpoint fails *at load* (validation/load race, e.g.
        storage going bad underneath us), it is quarantined too and the plan's
        next valid record — the last known good — is tried.
        """
        plan = self.resume_plan(quarantine=True)
        for record in plan.valid:
            try:
                # validation already streamed the hashes; load without re-hashing
                return load_checkpoint(record.path, shardings=shardings, verify=False)
            except Exception as exc:  # noqa: BLE001 - quarantine, then fall back
                with self._fs_lock:
                    if os.path.isdir(record.path):
                        quarantine_checkpoint(
                            record.path, f"load_failed: {exc}", root=self.directory
                        )
                plan.quarantined.append(record)
                continue
        return None

    def quarantined(self) -> list[dict[str, str]]:
        """Entries under ``corrupt/`` with their recorded reasons."""
        with self._fs_lock:
            return list_quarantined(self.directory)

    # -- retention -----------------------------------------------------------------
    def gc(self) -> list[int]:
        """Apply the retention policy; returns the steps actually deleted.

        Safety invariant (not policy-tunable): the newest checkpoint that
        passes validation is never deleted, even when ``keep_last_n`` newer —
        but corrupt — directories would otherwise crowd it out.
        """
        import shutil

        from .io import CheckpointCorrupt, validate_checkpoint

        with self._fs_lock:
            records = scan_checkpoints(self.directory, validate=False)
            by_step = {r.step: r.path for r in records if r.status == "valid"}
            doomed = self.retention.doomed(list(by_step))
            if doomed:
                # find the newest directory that actually validates; it is
                # exempt from deletion no matter what the policy says
                newest_valid: int | None = None
                for step in sorted(by_step, reverse=True):
                    try:
                        validate_checkpoint(by_step[step])
                    except CheckpointCorrupt:
                        continue
                    newest_valid = step
                    break
                doomed = [s for s in doomed if s != newest_valid]
            for step in doomed:
                shutil.rmtree(by_step[step], ignore_errors=True)
        return doomed

    # -- fault hooks -----------------------------------------------------------------
    def install_sigterm_handler(
        self,
        state_fn: Callable[[], tuple[int, Any]],
        deadline_s: float | None = None,
    ) -> Callable:
        """Emergency checkpoint on SIGTERM (pre-emption / queue kill).

        ``deadline_s`` is the platform's grace period (spot reclaim, SLURM
        grace, renewable-power window): the handler spends at most that long
        making the save durable — any in-flight async write gets the remaining
        budget to finish, the emergency write itself is synchronous, and the
        artificial experiment delays are skipped (a preemption save must never
        sleep on purpose).  Whether the deadline was met is recorded in the
        checkpoint metadata.

        Any previously installed SIGTERM handler is **chained** (invoked after
        the save), not clobbered — launchers and test harnesses keep their
        shutdown hooks.  Returns the installed handler (tests).
        """
        previous = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):  # pragma: no cover - exercised via subprocess
            t0 = time.monotonic()
            step, tree = state_fn()
            try:
                budget = None if deadline_s is None else max(
                    deadline_s - (time.monotonic() - t0), 0.01
                )
                self.wait(timeout=budget)
            except TimeoutError:
                pass  # pending write keeps running; the emergency save proceeds
            host_tree = jax.tree.map(jax.device_get, tree)
            delay_s, delay_mb = self.delay_s, self.delay_s_per_mb
            self.delay_s = self.delay_s_per_mb = 0.0
            try:
                elapsed = time.monotonic() - t0
                self._write(
                    step,
                    host_tree,
                    {
                        "emergency": True,
                        "deadline_s": deadline_s,
                        "met_deadline": (
                            True if deadline_s is None else elapsed < deadline_s
                        ),
                    },
                )
            finally:
                self.delay_s, self.delay_s_per_mb = delay_s, delay_mb
            if callable(previous):
                previous(signum, frame)
            elif previous == signal.SIG_DFL:
                # restore + re-raise so the default termination still happens
                signal.signal(signal.SIGTERM, signal.SIG_DFL)
                os.kill(os.getpid(), signal.SIGTERM)

        signal.signal(signal.SIGTERM, handler)
        return handler

    # -- monitoring -------------------------------------------------------------------
    def status_payload(self) -> dict[str, Any]:
        """JSON-ready view for the monitor's ``/checkpoints`` endpoint."""
        with self._lock:
            totals = {
                "n_saves": self.n_saves,
                "total_bytes": self.total_bytes,
                "total_blocking_seconds": self.total_blocking_seconds,
            }
        return {
            "directory": self.directory,
            "retention": self.retention.summary(),
            "checkpoints": [
                {"step": step, "path": path} for step, path in self.checkpoints()
            ],
            "quarantined": self.quarantined(),
            "resume": (
                self.last_resume_plan.summary() if self.last_resume_plan else None
            ),
            "totals": totals,
        }

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
