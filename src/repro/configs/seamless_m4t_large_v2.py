"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

Backbone only, per the assignment: the speech frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
24 encoder layers + 24 decoder layers (speech encoder + text decoder).
vocab 256206 is padded to 256256 for TP-16 divisibility (loss masks the pad).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    audio_frontend=True,
    rope_theta=10000.0,
    source="arXiv:2308.11596",
)

SMOKE_CONFIG = CONFIG.replace(
    name="seamless-m4t-large-v2-smoke", n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=250, attn_chunk=32,
)
