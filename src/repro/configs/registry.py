"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""

from __future__ import annotations

import importlib

from ..models.config import ArchConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "list_archs"]

#: arch id -> module name
_MODULES: dict[str, str] = {
    "glm4-9b": "glm4_9b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-14b": "qwen3_14b",
    "minitron-8b": "minitron_8b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-26b": "internvl2_26b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _module(arch).SMOKE_CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)
