"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8.  Trillion-parameter MoE (paper-table entry).
[arXiv:2501.kimi2; unverified]

Notes: head_dim pinned to 128 (64·128 ≠ d_model; q/o projections are
rectangular, standard for K2-class models).  ``d_ff`` is per-expert width.
Sharding: TP+FSDP+EP — at 1T parameters even the 512-chip multi-pod mesh
cannot hold AdamW train state (see EXPERIMENTS.md §Dry-run for honest
bytes-per-device numbers); the dry-run proves the sharding is coherent.
"""

from ..models.config import ArchConfig, MoESettings

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=128,
    rope_theta=50000.0,
    moe=MoESettings(n_experts=384, top_k=8, d_expert=2048),
    sharding="tp+fsdp",
    source="arXiv:2501.kimi2",
)

SMOKE_CONFIG = CONFIG.replace(
    name="kimi-k2-1t-a32b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab_size=256, head_dim=16, sharding="tp",
    moe=MoESettings(n_experts=8, top_k=2, d_expert=96), attn_chunk=32,
)
