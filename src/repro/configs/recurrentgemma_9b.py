"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 1 recurrent : 2 local-attn
pattern per the assignment, window 2048.  [arXiv:2402.19427; unverified]

``long_500k`` runs for this arch: the RG-LRU state is O(d) and the local
attention cache is a 2048-slot ring buffer, so the 524,288-token decode cell is
sub-quadratic (DESIGN.md §4).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10000.0,
    block_pattern=("rglru", "attn_local", "attn_local"),
    window=2048,
    sharding="tp+fsdp",
    source="arXiv:2402.19427",
)

SMOKE_CONFIG = CONFIG.replace(
    name="recurrentgemma-9b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab_size=256, head_dim=16, window=16,
    sharding="tp", attn_chunk=32,
)
