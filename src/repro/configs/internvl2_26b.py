"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
InternViT + InternLM2 backbone.  [arXiv:2404.16821; hf]

Backbone only, per the assignment: the vision frontend is a STUB —
``input_specs()`` provides 256 precomputed patch embeddings (B, 256, d_model)
prepended to the text sequence; seq_len counts the combined sequence.
vocab 92553 padded to 92672 for TP-16 divisibility.
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_vision_patches=256,
    rope_theta=1000000.0,
    sharding="tp+fsdp",
    source="arXiv:2404.16821",
)

SMOKE_CONFIG = CONFIG.replace(
    name="internvl2-26b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=250, n_vision_patches=8, sharding="tp", attn_chunk=32,
)
