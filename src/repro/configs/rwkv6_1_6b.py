"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent decay WKV recurrence.  [arXiv:2404.05892; unverified]

``long_500k`` runs for this arch: decode state is O(H·K·V) per layer,
independent of context length.
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,       # informational; WKV heads come from rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    source="arXiv:2404.05892",
)

SMOKE_CONFIG = CONFIG.replace(
    name="rwkv6-1.6b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, rwkv_head_dim=16, attn_chunk=32,
)
