"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 (kimi/moonlight).  [hf:moonshotai/Moonlight-16B-A3B; hf]

``d_ff`` is the per-expert FFN width (1408); experts are sharded over the
``model`` mesh axis (expert parallelism, 64/16 = 4 experts per shard).
"""

from ..models.config import ArchConfig, MoESettings

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    head_dim=128,
    rope_theta=50000.0,
    moe=MoESettings(n_experts=64, top_k=6, d_expert=1408),
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="moonshot-v1-16b-a3b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=96, vocab_size=256, head_dim=16,
    moe=MoESettings(n_experts=8, top_k=2, d_expert=96), attn_chunk=32,
)
