"""Nightly fault soak: train and adapt *through* injected failures, then
assert the system actually recovered.

Two drills, both deterministic from ``--seed``:

* **fleet drill** — a :class:`~repro.adapt.fleet.SimulatedFleet` under a
  :class:`~repro.adapt.controller.ControlLoop` takes a seeded
  :class:`~repro.faults.plan.FaultPlan` of slow/hang/restore events.  After
  the run the fleet must be healthy again (imbalance back under the detector
  threshold, or the wedged host evicted) and — the timing-infrastructure
  invariant — the timer database and counter set must be *bounded*: a control
  loop that allocates a new timer or counter per step would eventually OOM a
  long-running application, so the steady-state tail of the run (after the
  last injected fault has settled) may create no new names.

* **checkpoint drill** — a short real training run checkpoints into a temp
  directory; the drill then corrupts the newest checkpoint, plants killed-
  writer debris, and resumes.  The resumed run must select the newest *valid*
  step, quarantine every damaged directory with a reason, and finish.

Exit code is non-zero on any failed assertion — wire it as a scheduled CI job:

    PYTHONPATH=src python -m repro.faults.soak --seed 1 --steps 200
"""

from __future__ import annotations

import argparse
import os
import shutil
import statistics
import sys
import tempfile

__all__ = ["fleet_drill", "checkpoint_drill", "main"]


class SoakFailure(AssertionError):
    pass


def _check(ok: bool, message: str, failures: list[str]) -> None:
    status = "ok  " if ok else "FAIL"
    print(f"[soak] {status} {message}")
    if not ok:
        failures.append(message)


def fleet_drill(
    seed: int, steps: int, n_hosts: int = 4, n_micro: int = 8
) -> list[str]:
    """Run a fleet under seeded slow/hang/restore faults; return failures."""
    from ..adapt import ControlLoop
    from ..adapt.fleet import SimulatedFleet
    from ..core.timers import TimerDB
    from .inject import apply_fleet_event
    from .plan import FLEET_FAULTS, FaultPlan

    failures: list[str] = []
    db = TimerDB()
    fleet = SimulatedFleet(
        n_hosts, n_micro, window=4, threshold=1.5, evict_after=6, db=db
    )
    loop = ControlLoop(db=db)
    loop.register(fleet.controller)
    # faults only land in the first 3/4 of the run: the drill asserts
    # *recovery*, so the loop gets a deterministic grace window to converge
    plan = FaultPlan.random(
        seed, steps * 3 // 4, kinds=FLEET_FAULTS, rate=0.03, hosts=range(n_hosts)
    )
    print(f"[soak] fleet drill: {len(plan.events)} fault events over {steps} steps")
    # boundedness is measured over the steady-state tail: faults stop at 3/4,
    # detection windows and eviction streaks settle by 7/8, so from there to
    # the end a leak-free control loop creates zero new timer/counter rows
    # (a first-time eviction right after the midpoint is legitimate growth)
    mark = steps * 7 // 8
    mark_names: set[str] | None = None
    mark_counters: int | None = None
    for step in range(steps):
        for event in plan.at(step):
            if event.target in fleet.costs:
                print(f"[soak]   {event.describe()}")
                apply_fleet_event(event, fleet)
        fleet.run_step(step)
        loop.poll(step)
        if step == mark:
            mark_names = set(db.names())
            mark_counters = len(db.snapshot())
    # -- recovery: the end state is one the detector itself calls healthy ----
    # mirror the flagging rule (mean > threshold * median of host means): a
    # converged controller leaves no survivor above its own detection line
    seconds = {
        h: s for h, s in fleet.last_step_seconds.items() if h in fleet.plan.weights
    }
    median = max(statistics.median(seconds.values()), 1e-9)
    worst_ratio = max(seconds.values()) / median
    _check(
        worst_ratio <= fleet.detector.threshold * 1.05,
        f"fleet rebalanced: worst end host at {worst_ratio:.2f}x the median "
        f"(detector threshold {fleet.detector.threshold})",
        failures,
    )
    _check(
        len(fleet.plan.hosts) >= 1,
        f"fleet survived: {len(fleet.plan.hosts)} active hosts "
        f"({len(fleet.evicted)} evicted)",
        failures,
    )
    # -- boundedness: the steady-state tail created no new timers/counters ---
    grown = set(db.names()) - (mark_names or set())
    _check(
        not grown,
        f"timer set bounded: {len(grown)} new timers in tail {sorted(grown)[:5]}",
        failures,
    )
    _check(
        len(db.snapshot()) == mark_counters,
        f"snapshot bounded: {mark_counters} -> {len(db.snapshot())} rows",
        failures,
    )
    rebalances = sum(
        1 for a in loop.actions if a.action in ("rebalance", "restage", "restore")
    )
    print(
        f"[soak] fleet drill: {rebalances} plan adjustments, "
        f"{len(fleet.evicted)} evictions, {loop.polls} polls"
    )
    return failures


def checkpoint_drill(seed: int, steps: int = 12) -> list[str]:
    """Train, corrupt, kill, resume; return failures."""
    from ..launch.train import TrainSettings, run_training
    from .inject import bit_flip_leaf, simulate_writer_kill
    from .plan import seeded_rng

    failures: list[str] = []
    root = tempfile.mkdtemp(prefix="repro_soak_ckpt_")
    try:
        settings = TrainSettings(
            smoke=True, steps=steps, global_batch=2, seq_len=16,
            ckpt_dir=root, ckpt_mode="fixed", ckpt_every=max(steps // 3, 1),
            ckpt_synchronous=True, report_every=0, lr_total_steps=steps,
            pipeline_stages=1, pipeline_layers=4, pipeline_micro=2,
            pipeline_width=8,
        )
        first = run_training(settings)
        _check(first["iterations"] == steps, "first run completed", failures)
        ckpts = sorted(
            d for d in os.listdir(root)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        _check(len(ckpts) >= 2, f"first run left >= 2 checkpoints ({ckpts})", failures)
        if len(ckpts) < 2:
            return failures
        # damage the newest, plant killed-writer debris; both seeded
        rng = seeded_rng(seed, "soak", "ckpt")
        bit_flip_leaf(os.path.join(root, ckpts[-1]), rng=rng)
        simulate_writer_kill(root, steps + 1, rng=rng)
        resumed = run_training(
            TrainSettings(**{**settings.__dict__, "steps": steps + 4})
        )
        resume = resumed["resume"]
        expected = int(ckpts[-2].split("_")[1])
        _check(
            resume and resume["selected_step"] == expected,
            f"resume fell back to newest valid step {expected} "
            f"(selected {resume and resume['selected_step']})",
            failures,
        )
        reasons = {q["reason"] for q in (resume or {}).get("quarantined", ())}
        _check(
            "leaf_hash_mismatch" in reasons and "stale_tmp" in reasons,
            f"both injected faults quarantined with reasons ({sorted(reasons)})",
            failures,
        )
        _check(
            resumed["iterations"] == steps + 4, "resumed run completed", failures
        )
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200, help="fleet drill steps")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=12,
                    help="checkpoint drill training steps")
    ap.add_argument("--skip-fleet", action="store_true")
    ap.add_argument("--skip-checkpoint", action="store_true")
    args = ap.parse_args(argv)

    failures: list[str] = []
    if not args.skip_fleet:
        failures += fleet_drill(args.seed, args.steps, n_hosts=args.hosts)
    if not args.skip_checkpoint:
        failures += checkpoint_drill(args.seed, steps=args.train_steps)
    if failures:
        print(f"[soak] {len(failures)} FAILURE(S):", file=sys.stderr)
        for f in failures:
            print(f"[soak]   - {f}", file=sys.stderr)
        return 1
    print("[soak] all drills passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
