"""Deterministic, seedable fault plans.

A :class:`FaultPlan` is a replayable schedule of :class:`FaultEvent` records —
*which* failure hits *what* at *which* step.  Determinism is the whole point:
a soak run that found a recovery bug must be re-runnable byte-for-byte from
its seed, and every injector that needs randomness (which bit to flip, where
to truncate) draws from a per-event RNG derived from ``(seed, step, kind,
target)`` so replaying one event never depends on how many events ran before
it.

Event kinds (targets in parentheses):

======================  =======================================================
``bitflip``             flip one bit of a checkpoint leaf (leaf index)
``truncate_leaf``       cut a leaf file short (leaf index)
``drop_leaf``           delete a leaf file outright (leaf index)
``drop_manifest``       delete ``manifest.json``
``partial_manifest``    truncate the manifest mid-JSON (a writer crash between
                        leaf writes and commit)
``drop_commit``         delete the COMMIT marker
``kill_writer``         leave the stale ``.tmp`` debris of a writer killed
                        mid-write (partial leaves, no manifest, no commit)
``sigterm``             SIGTERM the process with a save deadline (arg=seconds)
``slow_host``           multiply a fleet host's per-unit cost (host, arg=factor)
``hang_host``           effectively stop a fleet host (host; arg=factor,
                        default 1000x)
``restore_host``        clear injected slowdowns on a host (host)
``kill_rank``           SIGKILL a real subprocess rank (host)
``hang_rank``           SIGSTOP a real subprocess rank — heartbeats stop but
                        the process lives (host)
``rejoin_rank``         launch a fresh rank that requests admission (host =
                        new host id)
``slow_rank``           throttle a real rank's step pacing (host, arg=factor)
======================  =======================================================
"""

from __future__ import annotations

import hashlib
import random
from collections.abc import Iterable, Sequence
from dataclasses import dataclass

__all__ = [
    "CHECKPOINT_FAULTS",
    "FLEET_FAULTS",
    "RANK_FAULTS",
    "FaultEvent",
    "FaultPlan",
    "seeded_rng",
]

#: the corruption matrix the checkpoint layer must detect and recover past
CHECKPOINT_FAULTS: tuple[str, ...] = (
    "bitflip",
    "truncate_leaf",
    "drop_leaf",
    "drop_manifest",
    "partial_manifest",
    "drop_commit",
    "kill_writer",
)

#: environment faults against a (simulated) fleet
FLEET_FAULTS: tuple[str, ...] = ("slow_host", "hang_host", "restore_host")

#: process-level faults against *real* subprocess ranks (the fleet drill:
#: SIGKILL / SIGSTOP a live rank, admit a fresh one, throttle one's pacing)
RANK_FAULTS: tuple[str, ...] = ("kill_rank", "hang_rank", "rejoin_rank", "slow_rank")


def _seed_int(*parts: object) -> int:
    """Stable cross-process integer seed from structured parts (``hash()`` is
    salted per process; ``random.seed`` only accepts scalars)."""
    digest = hashlib.sha256(repr(parts).encode()).digest()
    return int.from_bytes(digest[:8], "big")


def seeded_rng(*parts: object) -> random.Random:
    """Deterministic RNG keyed by structured parts — the standalone analogue
    of :meth:`FaultPlan.rng_for` for injections outside any plan."""
    return random.Random(_seed_int(*parts))


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: ``kind`` hits ``target`` at ``step``.

    ``target`` is a leaf index (checkpoint faults) or host id (fleet faults);
    ``arg`` is the kind-specific magnitude (slowdown factor, deadline
    seconds, truncate fraction).
    """

    step: int
    kind: str
    target: int | None = None
    arg: float | None = None

    def describe(self) -> str:
        bits = [f"step {self.step}: {self.kind}"]
        if self.target is not None:
            bits.append(f"target={self.target}")
        if self.arg is not None:
            bits.append(f"arg={self.arg:g}")
        return " ".join(bits)


class FaultPlan:
    """An ordered, seedable schedule of fault events.

    Build one explicitly from events, or draw a random-but-deterministic plan
    with :meth:`random`.  :meth:`at` returns the events due at a step;
    :meth:`rng_for` hands injectors their per-event RNG.
    """

    def __init__(self, events: Iterable[FaultEvent] = (), seed: int = 0) -> None:
        self.seed = int(seed)
        self.events: tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, e.kind, e.target if e.target is not None else -1))
        )

    @classmethod
    def random(
        cls,
        seed: int,
        n_steps: int,
        kinds: Sequence[str] = CHECKPOINT_FAULTS,
        rate: float = 0.05,
        hosts: Sequence[int] = (),
        max_leaf: int = 4,
    ) -> FaultPlan:
        """A deterministic plan: each step independently draws a fault with
        probability ``rate`` from ``kinds`` (fleet kinds target a random host
        from ``hosts``, checkpoint kinds a random leaf < ``max_leaf``)."""
        rng = random.Random(_seed_int("faultplan", seed))
        events: list[FaultEvent] = []
        for step in range(n_steps):
            if rng.random() >= rate:
                continue
            kind = rng.choice(list(kinds))
            if kind in FLEET_FAULTS or kind in RANK_FAULTS:
                if not hosts:
                    continue
                target = rng.choice(list(hosts))
                arg = (
                    round(rng.uniform(2.0, 8.0), 3)
                    if kind in ("slow_host", "slow_rank")
                    else None
                )
            elif kind == "sigterm":
                target, arg = None, round(rng.uniform(1.0, 10.0), 3)
            else:
                target = rng.randrange(max_leaf)
                arg = round(rng.uniform(0.1, 0.9), 3) if kind == "truncate_leaf" else None
            events.append(FaultEvent(step=step, kind=kind, target=target, arg=arg))
        return cls(events, seed=seed)

    def at(self, step: int) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def rng_for(self, event: FaultEvent) -> random.Random:
        """Per-event RNG: independent of plan order, stable across replays."""
        return random.Random(_seed_int(self.seed, event.step, event.kind, event.target))

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def describe(self) -> str:
        return "\n".join(e.describe() for e in self.events) or "(no events)"
