"""Fault injection: deterministic failure schedules for drills, tests, soaks.

The harshest environment change a long-running adaptive application must
survive is failure — preemption, corrupted storage, a dead or wedged host.
This package makes failure a first-class, *replayable* input:

* :class:`FaultPlan` / :class:`FaultEvent` (:mod:`repro.faults.plan`) — a
  seedable schedule of faults; every injector draws per-event randomness so
  a failing soak replays byte-for-byte from its seed;
* :mod:`repro.faults.inject` — the injectors: checkpoint corruption (the
  full matrix the validation layer must catch), fleet degradation
  (slow/hang/restore a simulated host), and process preemption (SIGTERM
  with a save deadline);
* :mod:`repro.faults.soak` — the nightly drill: train under a
  :class:`~repro.adapt.fleet.SimulatedFleet` with injected faults, assert
  recovery and bounded timer/counter growth.
"""

from .inject import (
    apply_checkpoint_event,
    apply_fleet_event,
    bit_flip_leaf,
    drop_commit,
    drop_leaf,
    drop_manifest,
    partial_manifest,
    send_sigterm,
    simulate_writer_kill,
    truncate_leaf,
)
from .plan import CHECKPOINT_FAULTS, FLEET_FAULTS, FaultEvent, FaultPlan, seeded_rng

__all__ = [
    "CHECKPOINT_FAULTS",
    "FLEET_FAULTS",
    "FaultEvent",
    "FaultPlan",
    "apply_checkpoint_event",
    "apply_fleet_event",
    "bit_flip_leaf",
    "drop_commit",
    "drop_leaf",
    "drop_manifest",
    "partial_manifest",
    "seeded_rng",
    "send_sigterm",
    "simulate_writer_kill",
    "truncate_leaf",
]
