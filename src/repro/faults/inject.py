"""Fault injectors: turn a :class:`~repro.faults.plan.FaultEvent` into real
damage — on disk (checkpoint corruption), on a simulated fleet (slow/hang a
host), or on the process (SIGTERM with a save deadline).

Checkpoint injectors operate on a published checkpoint directory and are the
exact inverse of what the validation layer must catch: a bit flipped in a leaf
(``leaf_hash_mismatch``), a truncated or deleted leaf (``leaf_size_mismatch``/
``missing_leaf``), a deleted or half-written manifest (``missing_manifest``/
``manifest_unreadable``), a dropped COMMIT marker (``missing_commit``), and
the stale ``.tmp`` debris of a writer killed mid-write (``stale_tmp``).  Every
injector is deterministic given an RNG (use
:meth:`~repro.faults.plan.FaultPlan.rng_for`).
"""

from __future__ import annotations

import json
import os
import random
import signal

import numpy as np

from .plan import FaultEvent

__all__ = [
    "apply_checkpoint_event",
    "apply_fleet_event",
    "bit_flip_leaf",
    "drop_commit",
    "drop_leaf",
    "drop_manifest",
    "partial_manifest",
    "send_sigterm",
    "simulate_writer_kill",
    "truncate_leaf",
]

_MANIFEST = "manifest.json"
_COMMIT = "COMMITTED"


def _leaf_files(ckpt_path: str) -> list[str]:
    names = sorted(n for n in os.listdir(ckpt_path) if n.startswith("leaf_"))
    if not names:
        raise FileNotFoundError(f"{ckpt_path}: no leaf files to corrupt")
    return names


def _pick_leaf(ckpt_path: str, leaf_index: int | None, rng: random.Random | None) -> str:
    names = _leaf_files(ckpt_path)
    if leaf_index is not None:
        return os.path.join(ckpt_path, names[leaf_index % len(names)])
    rng = rng if rng is not None else random.Random(0)
    return os.path.join(ckpt_path, rng.choice(names))


def bit_flip_leaf(
    ckpt_path: str, leaf_index: int | None = None, rng: random.Random | None = None
) -> str:
    """Flip one bit of one leaf file (silent storage corruption)."""
    rng = rng if rng is not None else random.Random(0)
    path = _pick_leaf(ckpt_path, leaf_index, rng)
    size = os.path.getsize(path)
    offset = rng.randrange(size)
    with open(path, "r+b") as f:
        f.seek(offset)
        byte = f.read(1)
        f.seek(offset)
        f.write(bytes([byte[0] ^ (1 << rng.randrange(8))]))
    return path


def truncate_leaf(
    ckpt_path: str,
    leaf_index: int | None = None,
    keep_fraction: float = 0.5,
    rng: random.Random | None = None,
) -> str:
    """Cut a leaf file short (partial write that still got committed)."""
    path = _pick_leaf(ckpt_path, leaf_index, rng)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(int(size * keep_fraction), 1))
    return path


def drop_leaf(
    ckpt_path: str, leaf_index: int | None = None, rng: random.Random | None = None
) -> str:
    """Delete a leaf file outright (lost object / unlinked extent)."""
    path = _pick_leaf(ckpt_path, leaf_index, rng)
    os.remove(path)
    return path


def drop_manifest(ckpt_path: str) -> str:
    path = os.path.join(ckpt_path, _MANIFEST)
    os.remove(path)
    return path


def partial_manifest(ckpt_path: str, keep_fraction: float = 0.5) -> str:
    """Truncate the manifest mid-JSON (writer crashed during the metadata
    write, after the leaves landed)."""
    path = os.path.join(ckpt_path, _MANIFEST)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(int(size * keep_fraction), 1))
    return path


def drop_commit(ckpt_path: str) -> str:
    path = os.path.join(ckpt_path, _COMMIT)
    os.remove(path)
    return path


def simulate_writer_kill(
    directory: str,
    step: int,
    n_leaves: int = 2,
    leaf_nbytes: int = 4096,
    rng: random.Random | None = None,
) -> str:
    """Leave exactly the debris a SIGKILLed writer leaves: a ``step_*.tmp``
    directory holding partial leaf files, no manifest, no COMMIT marker.

    The atomic-publish protocol means a killed writer can *only* produce this
    state (the final directory appears in one ``os.replace``), so tests and
    soaks inject it directly instead of racing a real subprocess kill.
    """
    rng = rng if rng is not None else random.Random(0)
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    os.makedirs(tmp, exist_ok=True)
    for i in range(n_leaves):
        arr = np.frombuffer(rng.randbytes(leaf_nbytes), dtype=np.uint8)
        path = os.path.join(tmp, f"leaf_{i:05d}.npy")
        with open(path, "wb") as f:
            np.save(f, arr)
        if i == n_leaves - 1:
            # the kill landed mid-write on the last leaf
            with open(path, "r+b") as f:
                f.truncate(max(leaf_nbytes // 2, 1))
    return tmp


def send_sigterm(pid: int | None = None) -> None:
    """Deliver the preemption notice (SIGTERM) — to this process by default.
    The checkpoint manager's installed handler owns the deadline semantics."""
    os.kill(pid if pid is not None else os.getpid(), signal.SIGTERM)


def apply_checkpoint_event(
    event: FaultEvent, ckpt_path: str, rng: random.Random | None = None
) -> str:
    """Dispatch one checkpoint-fault event against a checkpoint directory
    (``kill_writer`` targets the *parent* checkpoint root).  Returns the path
    the injector touched."""
    kind = event.kind
    if kind == "bitflip":
        return bit_flip_leaf(ckpt_path, event.target, rng)
    if kind == "truncate_leaf":
        return truncate_leaf(
            ckpt_path, event.target,
            keep_fraction=event.arg if event.arg is not None else 0.5, rng=rng,
        )
    if kind == "drop_leaf":
        return drop_leaf(ckpt_path, event.target, rng)
    if kind == "drop_manifest":
        return drop_manifest(ckpt_path)
    if kind == "partial_manifest":
        return partial_manifest(ckpt_path)
    if kind == "drop_commit":
        return drop_commit(ckpt_path)
    if kind == "kill_writer":
        root = os.path.dirname(os.path.abspath(ckpt_path))
        name = os.path.basename(ckpt_path.rstrip(os.sep))
        step = int(name.split("_")[1].split(".")[0]) + 1
        return simulate_writer_kill(root, step, rng=rng)
    raise ValueError(f"not a checkpoint fault kind: {kind!r}")


def apply_fleet_event(event: FaultEvent, fleet) -> None:
    """Dispatch one fleet-fault event against a
    :class:`~repro.adapt.fleet.SimulatedFleet` (or anything exposing
    ``slow_host`` / ``hang_host`` / ``restore_host``)."""
    kind = event.kind
    if event.target is None:
        raise ValueError(f"fleet fault {kind!r} needs a target host")
    if kind == "slow_host":
        fleet.slow_host(event.target, event.arg if event.arg is not None else 4.0)
    elif kind == "hang_host":
        fleet.hang_host(event.target, event.arg if event.arg is not None else 1000.0)
    elif kind == "restore_host":
        fleet.restore_host(event.target)
    else:
        raise ValueError(f"not a fleet fault kind: {kind!r}")
