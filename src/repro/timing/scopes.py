"""Hierarchical scopes — the call-path-facing half of the ``repro.timing``
facade.

A *scope* is a caliper window whose timer path is derived from runtime
nesting: ``scope("forward")`` inside ``scope("step")`` inside ``scope("train")``
records the timer ``train/step/forward`` with parent/child attribution taken
from the thread-local running stack (SPACE-Timers style — no nesting
annotations, the call structure *is* the hierarchy).  Two forms:

* :func:`scope` — dynamic: the path is joined under the enclosing scope at
  entry.  Use for cold/one-off regions and wherever the nesting varies.
* :func:`scope_handle` — pre-resolved: an **absolute** path resolved to its
  timer once; entering the returned handle is the array-backed fused
  start/stop window with zero dict lookups.  Use for hot loops.

:func:`counter` and :func:`timed` round out the surface: counters resolve
their channel name under the scope active *at resolution time* (resolve once,
bump lock-free forever), and the decorator opens a scope per call under
whatever scope the caller is running.
"""

from __future__ import annotations

import functools
from collections.abc import Callable, Iterator
from contextlib import contextmanager

from ..core.clocks import counter_cell
from ..core.timers import ScopeHandle, Timer, TimerDB, timer_db

__all__ = ["counter", "current_scope", "scope", "scope_handle", "timed"]


@contextmanager
def scope(name: str, db: TimerDB | None = None) -> Iterator[Timer]:
    """Open a hierarchical scope named ``name`` under the enclosing scope.

    The timer path is ``<enclosing path>/<name>`` (just ``name`` at top
    level); ``name`` may contain ``/`` segments of its own.  Yields the
    :class:`~repro.core.timers.Timer` so in-scope code can read it live.
    """
    db = db if db is not None else timer_db()
    with db.scope(name) as timer:
        yield timer


def scope_handle(path: str, db: TimerDB | None = None) -> ScopeHandle:
    """Pre-resolve an **absolute** scope path for hot-loop use.

    Resolution (path → timer object) happens once and is cached per
    database; ``with handle:`` is then the fused-sampler fast path — no
    name lookups, no database lock.  Parent attribution stays dynamic: each
    entry records whichever scope is active on the current thread.
    """
    db = db if db is not None else timer_db()
    return db.scope_handle(path)


def current_scope(db: TimerDB | None = None) -> str:
    """The calling thread's innermost active scope path (``""`` outside)."""
    db = db if db is not None else timer_db()
    return db.current_scope()


def counter(name: str, *, absolute: bool = False, db: TimerDB | None = None) -> Callable[[float], None]:
    """Resolve a lock-free counter cell, namespaced under the current scope.

    Returns the same C-level bound-append cell as
    :func:`repro.core.clocks.counter_cell`, with the channel name prefixed by
    the scope path active at *resolution* time (``counter("tokens")`` inside
    ``scope("serve")`` bumps channel ``serve/tokens``).  Resolve once, bump
    from any thread.  ``absolute=True`` skips the namespacing and addresses
    the process-global channel directly (e.g. channels a registered
    :class:`~repro.core.clocks.CounterClock` exports, like ``io_bytes``).

    Scoped (non-absolute) channels are auto-exported through the session
    CounterClock (:func:`repro.timing.session.export_counter_channel`), so
    they render in timer reports without any manual clock registration;
    absolute names are left alone — they usually address channels an existing
    clock already exports, and double-exporting would collide.
    """
    if not absolute:
        path = (db if db is not None else timer_db()).current_scope()
        if path:
            name = f"{path}/{name}"
        from .session import export_counter_channel

        export_counter_channel(name)
    return counter_cell(name)


def timed(name: str | None = None, db: TimerDB | None = None) -> Callable:
    """Decorator opening a scope around every call of the function.

    Unlike the removed flat ``repro.core.timers.timed``, the scope nests
    under the **caller's** active scope at call time: a helper decorated
    ``@timed("build")`` called from inside ``scope("train")`` records
    ``train/build``; the same helper called bare records ``build``.  The
    default name is the function's qualified name.
    """

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            d = db if db is not None else timer_db()
            with d.scope(label):
                return fn(*args, **kwargs)

        return wrapper

    return deco
