"""``repro.timing`` — the public facade over the timer infrastructure.

One coherent, hierarchical, scope-based API replacing the four overlapping
entry points that grew around the flat Cactus-style timer database
(``TimerDB.start/stop`` by name or handle, ``db.timing(...)``, the flat
``timed()`` decorator, raw counter cells):

* **Scopes** (write side): ``with scope("forward"):`` nests under the
  enclosing scope via the thread-local running stack, forming path-addressed
  timers (``train/step/forward``).  Hot loops pre-resolve a path once with
  ``h = scope_handle("train/step")`` and enter the handle — the array-backed
  fused start/stop window with zero dict lookups.
* **Counters**: ``counter("tokens")`` resolves the lock-free counter cell,
  namespaced under the scope active at resolution time.
* **Decorator**: ``@timed()`` opens a scope per call under the *caller's*
  active scope.
* **Sessions**: ``with session() as ts:`` bundles a database + scheduler +
  control loop and installs the database as the process default — no more
  ``reset_timer_db()`` juggling.
* **Read side**: ``tree()`` builds the parent/child forest with inclusive and
  exclusive (self minus children) seconds; ``format_tree()`` renders the
  hierarchical Fig.-2 report; ``total_seconds("serve")`` rolls up whole path
  segments.

The old surfaces keep working (``repro.core`` re-exports are unchanged;
``db.timing``/``core.timers.timed`` emit ``DeprecationWarning``); this module
is the supported way in.  Guarded by ``tests/test_api_surface.py``.
"""

from ..core.timers import ScopeHandle, Timer, TimerDB, TimerNode, timer_db
from .reporting import format_tree, total_seconds, tree
from .scopes import counter, current_scope, scope, scope_handle, timed
from .session import TimingSession, current_session, session

__all__ = [
    "ScopeHandle",
    "Timer",
    "TimerDB",
    "TimerNode",
    "TimingSession",
    "counter",
    "current_scope",
    "current_session",
    "format_tree",
    "scope",
    "scope_handle",
    "session",
    "timed",
    "timer_db",
    "total_seconds",
    "tree",
]
