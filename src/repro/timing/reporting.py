"""Read-side facade helpers: the tree and rollup queries over the active
database (explicit ``db=`` overrides; otherwise the entered
:class:`~repro.timing.session.TimingSession`'s database, falling back to the
process-global one)."""

from __future__ import annotations

from ..core.report import format_tree_report
from ..core.timers import TimerDB, TimerNode, timer_db

__all__ = ["format_tree", "total_seconds", "tree"]


def tree(db: TimerDB | None = None) -> list[TimerNode]:
    """The parent/child timer forest (inclusive + exclusive seconds per node)."""
    db = db if db is not None else timer_db()
    return db.tree()


def format_tree(db: TimerDB | None = None, prefix: str = "", title: str = "Timer tree") -> str:
    """Render the hierarchical Fig.-2 report (indented inclusive/exclusive table)."""
    db = db if db is not None else timer_db()
    return format_tree_report(db, title=title, prefix=prefix)


def total_seconds(prefix: str = "", db: TimerDB | None = None) -> float:
    """Rollup: wall seconds summed over the timers at/under ``prefix``
    (whole path segments — ``"serve"`` never matches ``server_x``)."""
    db = db if db is not None else timer_db()
    return db.total_seconds(prefix)
