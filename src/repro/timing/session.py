"""Session wiring — one object bundling the timing stack for a run.

:class:`TimingSession` owns a :class:`~repro.core.timers.TimerDB`, a
:class:`~repro.core.schedule.Scheduler` over it, and a
:class:`~repro.adapt.controller.ControlLoop` polling it (both built lazily),
plus the read side (flat report, tree report, forest).  Entering the session
installs its database as the process default, so every API that falls back to
:func:`repro.core.timers.timer_db` — scopes, counters, reports, straggler
detectors, monitors — records into the session for its lifetime; exiting
restores the previous database.  This replaces the
``timer_db()``/``reset_timer_db()`` global juggling tests and launchers used
to do by hand::

    with timing.session() as ts:
        with timing.scope("work"):
            ...
        print(ts.tree_report())

Sessions nest (the previous database is restored on exit) but are a
process-wide default, not thread-local: enter them from the driving thread.
"""

from __future__ import annotations

import threading

from ..core.clocks import CounterClock, channel_layout, clock_names, register_clock
from ..core.report import format_report, format_tree_report, tree_rows
from ..core.timers import ScopeHandle, Timer, TimerDB, TimerNode, _install_db
from .scopes import counter as _counter

__all__ = ["TimingSession", "current_session", "session"]

_ACTIVE: list[TimingSession] = []
_ACTIVE_LOCK = threading.Lock()

#: channels exported through the auto-registered session CounterClock.  The
#: map is process-global (counters themselves are process-global channels) and
#: additive: every scoped counter any session resolves becomes readable; the
#: clock is never auto-unregistered, because a layout rebuild drops
#: accumulated values for channels that vanish — reports formatted *after* a
#: session exits must still render its counters.
_SESSION_COUNTER_UNITS: dict[str, str] = {}
_SESSION_CLOCK_NAME = "session_counters"
_SESSION_CLOCK_LOCK = threading.Lock()


def export_counter_channel(channel: str, unit: str = "count") -> None:
    """Make ``channel`` readable by every timer window from now on.

    Scoped counters (``timing.counter("tokens")`` inside ``scope("serve")``)
    write to process-global channels that no built-in clock exports; without
    this, they are write-only — bumpable but invisible in reports.  The first
    resolution of each such channel re-registers the shared
    ``session_counters`` :class:`~repro.core.clocks.CounterClock` with the
    channel added (a registry version bump), so every timer picks it up from
    its next window and ``format_report(..., channels=("serve/tokens",))``
    renders it with zero manual clock setup.  A channel some other clock
    already exports is skipped — double-exporting would force the collision
    rename onto the established name.
    """
    with _SESSION_CLOCK_LOCK:
        if _SESSION_CLOCK_NAME not in clock_names():
            # a registry reset (e.g. test isolation) dropped the clock: the
            # channel cache is stale, rebuild from scratch
            _SESSION_COUNTER_UNITS.clear()
        elif channel in _SESSION_COUNTER_UNITS:
            return
        if channel_layout().flat_index.get(channel) is not None:
            # some other clock already exports this exact channel name;
            # double-exporting would force the collision rename on both
            return
        _SESSION_COUNTER_UNITS[channel] = unit
        units = dict(_SESSION_COUNTER_UNITS)
        # register inside the lock: two concurrent first-resolutions must not
        # let a stale (smaller) channel snapshot win the registration race
        register_clock(
            _SESSION_CLOCK_NAME, lambda: CounterClock(_SESSION_CLOCK_NAME, units)
        )


class TimingSession:
    """A self-contained timing stack: database + scheduler + control loop.

    Parameters
    ----------
    db:
        Timer database to bundle; a fresh one by default (pass
        ``timer_db()`` to wrap the current process default instead).
    scheduler / control_loop:
        Pre-built components to adopt; otherwise constructed lazily over
        ``db`` on first access (the control loop import is deferred so the
        facade stays import-light).
    """

    def __init__(
        self,
        db: TimerDB | None = None,
        *,
        scheduler=None,
        control_loop=None,
    ) -> None:
        self.db = db if db is not None else TimerDB()
        self._scheduler = scheduler
        self._control_loop = control_loop
        self._prev_dbs: list[TimerDB] = []

    # -- bundled components ----------------------------------------------------
    @property
    def scheduler(self):
        """The session's Cactus-bin scheduler (built over ``db`` on first use)."""
        if self._scheduler is None:
            from ..core.schedule import Scheduler

            self._scheduler = Scheduler(self.db)
        return self._scheduler

    @property
    def control_loop(self):
        """The session's runtime-adaptation loop (built over ``db`` on first
        use).  Register controllers on it and attach it to a schedule bin with
        ``session.scheduler.attach_control_loop(session.control_loop)``."""
        if self._control_loop is None:
            from ..adapt.controller import ControlLoop

            self._control_loop = ControlLoop(self.db)
        return self._control_loop

    # -- activation --------------------------------------------------------------
    def __enter__(self) -> TimingSession:
        self._prev_dbs.append(_install_db(self.db))
        with _ACTIVE_LOCK:
            _ACTIVE.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        _install_db(self._prev_dbs.pop())
        with _ACTIVE_LOCK:
            for i in range(len(_ACTIVE) - 1, -1, -1):
                if _ACTIVE[i] is self:
                    del _ACTIVE[i]
                    break

    # -- write-side sugar --------------------------------------------------------
    def scope(self, name: str):
        """Hierarchical scope on this session's database (see
        :func:`repro.timing.scope`)."""
        return self.db.scope(name)

    def scope_handle(self, path: str) -> ScopeHandle:
        """Pre-resolved absolute-path handle on this session's database."""
        return self.db.scope_handle(path)

    def counter(self, name: str, *, absolute: bool = False):
        """Scope-namespaced counter cell resolved against this session."""
        return _counter(name, absolute=absolute, db=self.db)

    def timer(self, ref: int | str) -> Timer:
        return self.db.get(ref)

    # -- read side ---------------------------------------------------------------
    def tree(self) -> list[TimerNode]:
        """The session's parent/child timer forest."""
        return self.db.tree()

    def total_seconds(self, prefix: str = "") -> float:
        """Segment-matched rollup over the session's timers."""
        return self.db.total_seconds(prefix)

    def report(self, **kwargs) -> str:
        """The flat Fig.-2 table (plus the ``ADAPT/`` decision log when the
        session's control loop has been used)."""
        kwargs.setdefault("adapt", self._control_loop)
        return format_report(self.db, **kwargs)

    def tree_report(self, **kwargs) -> str:
        """The hierarchical Fig.-2 table (inclusive/exclusive seconds)."""
        return format_tree_report(self.db, **kwargs)

    def tree_rows(self, prefix: str = "") -> list[dict[str, object]]:
        """Nested JSON-ready tree rows (the monitor's ``/tree`` payload)."""
        return tree_rows(self.db, prefix=prefix)

    def snapshot(self) -> dict[str, dict[str, float]]:
        return self.db.snapshot()


def session(db: TimerDB | None = None, **kwargs) -> TimingSession:
    """Build a :class:`TimingSession` (sugar mirroring ``with session():``)."""
    return TimingSession(db, **kwargs)


def current_session() -> TimingSession | None:
    """The innermost entered session, or ``None`` outside any."""
    with _ACTIVE_LOCK:
        return _ACTIVE[-1] if _ACTIVE else None
