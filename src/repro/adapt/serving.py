"""Serving adaptation: batch-width steering + SLO load-shedding as a
:class:`~repro.adapt.controller.Controller`.

This is the serving half of the paper's thesis at production scale — the same
:class:`~repro.adapt.controller.ControlLoop` that rebalances training
microbatches polls this controller, so *every* runtime decision the system
takes (training or serving) lands in one decision log and one ``ADAPT/``
report section.  It replaces the private halve/double rule the old static
engine buried in ``_steer_batch_size``: decisions are now driven by the
``serve/decode`` timer channel (what the engine *measured*, not what it
guessed inline), applied through the steerable ``serving.max_active``
parameter and the engine's ``shed`` actuator, and recorded as
``ADAPT/serving::grow_batch`` / ``shrink_batch`` / ``shed`` rows.

Decision rules per poll (all gated on fresh measurements since the last
poll, with a post-action cooldown so a resize is judged on windows measured
*at* the new width):

* **shrink_batch** — decode-step latency above ``slo.target_decode_ms``:
  halve the admission width (floor 1).  Decode serves every in-flight
  request at once, so step latency is the per-token cadence every user sees.
* **grow_batch** — latency under ``slo.grow_headroom * target`` with
  requests waiting and width below the slot count: double the width.
* **shed** — the estimated tail queueing delay (queue depth over the
  measured completion rate, :func:`repro.serving.slo.shed_count`) exceeds
  ``slo.max_queue_delay_s``: drop exactly enough queued requests to meet the
  objective again.
"""

from __future__ import annotations

from collections.abc import Iterable, Mapping

from ..core.params import ParamRegistry, param_registry
from ..serving.slo import ServiceLevel, shed_count
from .controller import ControlAction, Measurement

__all__ = ["ServingControl"]


class ServingControl:
    """Controller steering one :class:`~repro.serving.engine.ServeSession`.

    Parameters
    ----------
    engine:
        The serving engine to steer (exposes ``max_active``/``n_slots``,
        ``queue_depth``, ``completion_rate()`` and the ``shed`` actuator).
    slo:
        Objectives to enforce; defaults to the engine's own.  With neither
        ``target_decode_ms`` nor ``max_queue_delay_s`` set the controller
        observes but never acts.
    registry:
        Steerable-parameter registry holding ``serving.max_active`` (the
        process default when ``None`` — pass the engine's).
    cooldown:
        Polls to skip after a resize, so the next decision is based on
        windows measured entirely at the new width.
    """

    name = "serving"
    channels = ("serve/prefill", "serve/decode")

    def __init__(
        self,
        engine,
        slo: ServiceLevel | None = None,
        *,
        registry: ParamRegistry | None = None,
        cooldown: int = 2,
    ) -> None:
        self.engine = engine
        self.slo = slo if slo is not None else engine.slo
        self._registry = registry if registry is not None else param_registry()
        self.cooldown = cooldown
        self._cooldown_left = 0
        self._prev_decode = Measurement(0.0, 0)

    # -- measurement windows -----------------------------------------------------
    def _decode_step_ms(self, measurements: Mapping[str, Measurement]) -> float | None:
        """Mean decode-step latency over the windows since the last poll
        (``None`` when no decode ran in between — nothing to judge)."""
        decode = measurements["serve/decode"]
        d_sec = decode.seconds - self._prev_decode.seconds
        d_cnt = decode.count - self._prev_decode.count
        self._prev_decode = decode
        if d_cnt <= 0:
            return None
        return 1e3 * d_sec / d_cnt

    # -- dispatch ----------------------------------------------------------------
    def control(
        self, step: int, measurements: Mapping[str, Measurement]
    ) -> Iterable[ControlAction]:
        actions: list[ControlAction] = []
        step_ms = self._decode_step_ms(measurements)

        # shedding first: queue pressure is judged every poll, resize or not
        n_shed = shed_count(self.engine.queue_depth, self.engine.completion_rate(), self.slo)
        if n_shed:
            dropped = self.engine.shed(n_shed)
            actions.append(
                ControlAction(
                    step=step, controller=self.name, trigger="serve/queue_depth",
                    action="shed",
                    detail={
                        "n": len(dropped),
                        "rids": tuple(r.rid for r in dropped),
                        "queue_depth": self.engine.queue_depth,
                        "max_queue_delay_s": self.slo.max_queue_delay_s,
                    },
                )
            )

        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            return actions
        if self.slo.target_decode_ms is None or step_ms is None:
            return actions

        width = self.engine.max_active
        if step_ms > self.slo.target_decode_ms and width > 1:
            new_width = max(width // 2, 1)
            self._resize(new_width)
            actions.append(self._resize_action(step, "shrink_batch", step_ms, width, new_width))
        elif (
            step_ms < self.slo.grow_headroom * self.slo.target_decode_ms
            and width < self.engine.n_slots
            and self.engine.queue_depth > 0
        ):
            new_width = min(width * 2, self.engine.n_slots)
            self._resize(new_width)
            actions.append(self._resize_action(step, "grow_batch", step_ms, width, new_width))
        return actions

    def _resize(self, new_width: int) -> None:
        self._registry.set("serving.max_active", new_width)
        self._cooldown_left = self.cooldown

    def _resize_action(
        self, step: int, verb: str, step_ms: float, width: int, new_width: int
    ) -> ControlAction:
        return ControlAction(
            step=step, controller=self.name, trigger="serve/decode", action=verb,
            detail={
                "decode_step_ms": step_ms,
                "target_ms": self.slo.target_decode_ms,
                "max_active": f"{width}->{new_width}",
            },
        )
