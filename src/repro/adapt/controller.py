"""The control plane core: controllers, decisions, and the polling loop.

The paper's headline claim is that a timing infrastructure lets an application
"profile itself and dynamically adapt itself to a changing environment at run
time".  The measurement side (clocks, timers, cross-host reductions) lives in
:mod:`repro.core` and :mod:`repro.dist`; this module closes the loop:

* a :class:`Controller` is anything that reads measurements and decides — it
  names the timer-database channels it wants polled and returns zero or more
  :class:`ControlAction` records per step;
* the :class:`ControlLoop` is the registry and dispatcher: each
  :meth:`ControlLoop.poll` samples every registered controller's channels out
  of the :class:`~repro.core.timers.TimerDB` and hands them over, records each
  returned action in its decision log, and mirrors per-action counts into the
  database as ``ADAPT/<controller>::<action>`` rows so adaptation history
  renders in the Fig.-2 report next to every measured timer.

The loop is deliberately synchronous and schedulable: drive it from a Cactus
bin via :meth:`repro.core.schedule.Scheduler.attach_control_loop` (the
production path in ``repro.launch.train``) or call ``poll`` by hand in tests
and simulations.  Controllers in this package: checkpoint admission
(:mod:`repro.adapt.checkpoint`, the paper's AdaptCheck generalized) and
straggler response (:mod:`repro.adapt.stragglers`, rebalance/evict).
"""

from __future__ import annotations

from collections.abc import Callable, Iterable, Mapping, Sequence
from dataclasses import dataclass, field
from typing import NamedTuple, Protocol, runtime_checkable

from ..core.timers import TimerDB, timer_db

__all__ = ["Measurement", "ControlAction", "Controller", "ControlLoop"]


class Measurement(NamedTuple):
    """One polled timer-DB channel: accumulated seconds + window count."""

    seconds: float
    count: int


@dataclass(frozen=True)
class ControlAction:
    """One decision taken by a controller — the unit of the ``ADAPT/`` log.

    ``trigger`` names the timer-DB channel whose measurement caused the
    decision (e.g. ``DIST/host2::step``); ``action`` is the short verb
    (``rebalance``, ``evict``, ``checkpoint``); ``detail`` carries
    action-specific parameters for the report.
    """

    step: int
    controller: str
    trigger: str
    action: str
    detail: Mapping[str, object] = field(default_factory=dict)

    def describe(self) -> str:
        parts = " ".join(f"{k}={_fmt(v)}" for k, v in self.detail.items())
        return f"[{self.controller}] step {self.step}: {self.action} <- {self.trigger} {parts}"


def _fmt(value: object) -> str:
    if isinstance(value, float):
        return f"{value:.4g}"
    return str(value)


@runtime_checkable
class Controller(Protocol):
    """Anything the :class:`ControlLoop` can dispatch.

    ``channels`` lists the timer names this controller may read each poll;
    ``control`` receives them as a lazy mapping — a channel is sampled from
    the timer database only when the controller actually accesses it, so a
    controller that skips a poll (or consults richer sources, like the
    straggler controller's detector) costs zero timer reads.  ``control``
    returns the actions taken (empty when the controller decides to do
    nothing); the declared channels document the trigger surface and appear
    in every recorded action.
    """

    name: str
    channels: Sequence[str]

    def control(
        self, step: int, measurements: Mapping[str, Measurement]
    ) -> Iterable[ControlAction]: ...


class _LazyMeasurements(Mapping):
    """Mapping over a controller's declared channels, sampled on first access.

    ``ControlLoop.poll`` hands one of these to every controller: the locked
    timer-database reads happen only for channels the controller actually
    looks at this poll (cached per poll), so declaring a wide trigger surface
    — e.g. one ``DIST/host{h}::step`` channel per host on a large fleet — is
    free on the polls that skip it.
    """

    __slots__ = ("_measure", "_channels", "_cache")

    def __init__(self, measure, channels) -> None:
        self._measure = measure
        self._channels = tuple(channels)
        self._cache: dict[str, Measurement] = {}

    def __getitem__(self, name: str) -> Measurement:
        if name not in self._channels:
            raise KeyError(name)
        got = self._cache.get(name)
        if got is None:
            got = self._cache[name] = self._measure(name)
        return got

    def __iter__(self):
        return iter(self._channels)

    def __len__(self) -> int:
        return len(self._channels)


class ControlLoop:
    """Controller registry + dispatcher + ``ADAPT/`` decision log.

    Parameters
    ----------
    db:
        Timer database to poll channels from and publish decision rows into
        (defaults to the process-global database).
    prefix:
        Section prefix for published decision rows (``ADAPT``).
    publish:
        When true (default), every action increments an
        ``{prefix}/<controller>::<action>`` timer row so aggregate adaptation
        counts render in ``core.report.format_report``.
    on_action:
        Optional callback invoked with each recorded :class:`ControlAction`
        (launcher logging / alerting hook).
    """

    def __init__(
        self,
        db: TimerDB | None = None,
        prefix: str = "ADAPT",
        publish: bool = True,
        on_action: Callable[[ControlAction], None] | None = None,
    ) -> None:
        self._db = db if db is not None else timer_db()
        self.prefix = prefix
        self.publish = publish
        self.on_action = on_action
        self._controllers: list[Controller] = []
        #: every action ever recorded, in dispatch order — the ADAPT/ log
        self.actions: list[ControlAction] = []
        self.polls = 0

    @property
    def db(self) -> TimerDB:
        return self._db

    # -- registry ---------------------------------------------------------------
    def register(self, controller: Controller) -> Controller:
        """Add a controller; names must be unique within the loop."""
        name = getattr(controller, "name", None)
        if not name:
            raise ValueError(f"controller {controller!r} has no name")
        if any(c.name == name for c in self._controllers):
            raise ValueError(f"controller {name!r} already registered")
        self._controllers.append(controller)
        return controller

    def unregister(self, name: str) -> None:
        before = len(self._controllers)
        self._controllers = [c for c in self._controllers if c.name != name]
        if len(self._controllers) == before:
            raise ValueError(f"no controller named {name!r}")

    def controller(self, name: str) -> Controller:
        for c in self._controllers:
            if c.name == name:
                return c
        raise ValueError(f"no controller named {name!r}")

    def controllers(self) -> list[str]:
        return [c.name for c in self._controllers]

    # -- dispatch ---------------------------------------------------------------
    def _measure(self, channel: str) -> Measurement:
        if self._db.exists(channel):
            timer = self._db.get(channel)
            return Measurement(timer.seconds(), timer.count)
        return Measurement(0.0, 0)

    def poll(self, step: int) -> list[ControlAction]:
        """Dispatch every controller with lazily sampled channels; returns
        the actions taken this step (also appended to :attr:`actions`)."""
        self.polls += 1
        taken: list[ControlAction] = []
        for controller in list(self._controllers):
            measurements = _LazyMeasurements(
                self._measure, getattr(controller, "channels", ())
            )
            for action in controller.control(step, measurements) or ():
                self._record(action)
                taken.append(action)
        return taken

    def _record(self, action: ControlAction) -> None:
        self.actions.append(action)
        if self.publish:
            # cached path→timer resolution (repro.timing scope handles): the
            # locked create/lookup happens once per distinct action row
            scope = self._db.scope_handle(
                f"{self.prefix}/{action.controller}::{action.action}"
            )
            scope.timer.count += 1
        if self.on_action is not None:
            self.on_action(action)

    # -- reporting ---------------------------------------------------------------
    def actions_for(self, controller: str) -> list[ControlAction]:
        return [a for a in self.actions if a.controller == controller]

    def summary(self) -> dict:
        counts: dict[str, int] = {}
        for a in self.actions:
            key = f"{a.controller}::{a.action}"
            counts[key] = counts.get(key, 0) + 1
        return {
            "polls": self.polls,
            "controllers": self.controllers(),
            "n_actions": len(self.actions),
            "action_counts": counts,
        }
