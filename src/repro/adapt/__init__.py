"""Runtime adaptation — the control plane that turns timer measurements into
actions (the paper's "profile itself and dynamically adapt itself to a
changing environment at run time", Sec. 1 & 3).

The layering:

.. code-block:: text

    repro.core            measure   clocks -> timers -> TimerDB -> report
    repro.dist            reduce    per-host step times -> StragglerDetector
    repro.adapt (here)    decide    Controller registry polled by ControlLoop
    launcher / fleet      act       rebalance plans, evict hosts, rebuild
                                    meshes, admit checkpoints

``ControlLoop`` polls each registered :class:`Controller`'s timer-DB channels
once per step and records every decision as an ``ADAPT/`` row in the decision
log and the Fig.-2 report.  Shipped controllers: :class:`CheckpointControl`
(AdaptCheck admission, paper Sec. 3.2), :class:`StragglerResponse`
(rebalance microbatch shares, evict persistent stragglers, trigger mesh
rebuilds), and :class:`ServingControl` (serving batch-width steering + SLO
load-shedding — training and serving adaptation share this one loop).
:class:`SimulatedFleet` packages an n-host, CPU-only simulation of the whole
loop for tests and demos.
"""

from .checkpoint import CheckpointControl
from .controller import ControlAction, Controller, ControlLoop, Measurement
from .fleet import SimulatedFleet
from .serving import ServingControl
from .stragglers import StragglerResponse

__all__ = [
    "ControlAction",
    "Controller",
    "ControlLoop",
    "Measurement",
    "CheckpointControl",
    "ServingControl",
    "StragglerResponse",
    "SimulatedFleet",
]
