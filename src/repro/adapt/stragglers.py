"""Straggler response: rebalance microbatch shares, then evict — the paper's
"dynamically adapt itself to a changing environment" loop closed at fleet
scale (cf. the Cactus Worm migration experiments, Allen et al. cs/0108001:
measure, decide, migrate).

:class:`StragglerResponse` sits between the measurement reduction
(:class:`~repro.dist.stragglers.StragglerDetector`, fed by all hosts through
an injectable transport) and three actuators:

* **restage** — when the confirmed straggler *owns a pipeline stage* (the
  controller was given a :class:`~repro.dist.pipeline.StagePlan` and a
  ``host -> stage`` map), move the stage boundaries: derate the stage's
  capacity weight by the same equilibrium rule so the largest-remainder depth
  apportionment sheds whole layers off the slow device.  A stage owner's work
  is depth-bound (every microbatch traverses its stage), so the microbatch
  share derate would move no work for it: when the boundary cannot shift any
  further, escalation goes straight to the eviction streak backstop;
* **rebalance** — set the flagged host's weight in the fleet's
  :class:`~repro.dist.pipeline.MicrobatchPlan` to its equilibrium (nominal
  weight / per-microbatch slowdown, floored at ``min_weight``), so its share
  of the pipelined microbatches matches its degraded capacity.  Slowdown is
  *share-normalized*: a host deliberately provisioned with a larger weight is
  not "slow" merely for taking proportionally longer steps;
* **restore** — the inverse: a derated host whose per-unit time is back in
  line (the slowdown was transient — a noisy neighbor, thermal throttling
  that cleared) earns its weight back by the same equilibrium rule, capped at
  its *original* weight, so a one-off hiccup never permanently costs the
  fleet capacity;
* **evict** — when a host stays flagged at the minimum weight (it is too slow
  to be worth its guaranteed share) or keeps getting flagged past the streak
  backstop, remove it: from the plan, from the detector's median, and from
  the transport, then hand the host to ``on_evict`` so the launcher rebuilds
  its mesh (:func:`repro.dist.meshutil.remove_host`).

After every weight change the host's detector window and streak are reset:
samples measured under the old assignment no longer describe the host, and
judging the new assignment on stale samples compounds derates into spurious
evictions of already-fixed hosts.

Every decision is returned as a :class:`ControlAction` so the control loop
records it in the ``ADAPT/`` log with the triggering ``DIST/host{h}::step``
channel.
"""

from __future__ import annotations

import statistics
from collections.abc import Callable, Mapping

from ..dist.pipeline import MicrobatchPlan, StagePlan
from ..dist.stragglers import StragglerDetector, StragglerReport
from .controller import ControlAction, Measurement

__all__ = ["StragglerResponse"]

#: shared stepped-probe policy for both granularity probes (microbatch share
#: in :meth:`StragglerResponse._weight_dropping_share`, stage depth in
#: :meth:`StragglerResponse._try_restage`): weights decay by this factor per
#: probe until the ``min_weight`` floor (within the epsilon guard) — tune it
#: here so the two actuators keep identical exhaustion semantics
_PROBE_DECAY = 0.75
_PROBE_FLOOR_EPS = 1e-12


class StragglerResponse:
    """Rebalance-then-evict policy over a straggler detector and a plan.

    Parameters
    ----------
    detector:
        The cross-host reduction point.  The controller drains its transport
        and runs :meth:`~repro.dist.stragglers.StragglerDetector.check` every
        ``check_every`` polls.
    plan:
        The fleet's microbatch assignment to act on.
    check_every:
        Polls between fleet checks (mirrors a launcher checking every N
        steps; the check fires on polls where ``(step + 1) % check_every == 0``).
    confirm_after:
        Consecutive flagged checks before the first rebalance — one flagged
        window can be a transient (GC pause, kernel hiccup); a confirmed
        straggler is one that stays slow.
    evict_after:
        Consecutive flagged checks after which the host is evicted regardless
        of weight.  The streak resets on every weight change, whenever the
        flag turns out share-induced (per-unit time fine), and whenever the
        raw flag clears — so escalation only counts checks where the host was
        genuinely slow and no rebalance could absorb it (already at the
        weight floor, or share granularity exhausted).
    min_weight:
        Floor for a rebalanced host's weight.  A host flagged *at* the floor
        has already been derated as far as policy allows and is evicted.
    rel_tol:
        Minimum relative weight change worth acting on (hysteresis guard
        against churning the plan for measurement noise).
    local_feed:
        Optional ``(host, timer_name)``: each poll additionally samples this
        process's own step timer straight out of the timer database — the
        single-process path the training launcher uses alongside (or instead
        of) a transport.
    stage_plan / stage_for_host:
        Optional pipeline-stage wiring: ``stage_plan`` is the fleet's
        :class:`~repro.dist.pipeline.StagePlan` and ``stage_for_host`` maps a
        host id to the pipeline stage it owns.  A confirmed straggler that
        owns a stage is answered with a **restage** (stage weight derated by
        the equilibrium rule until the depth apportionment actually sheds a
        layer off *its* stage); when the boundary cannot move further (stage
        already at one layer, or weight floor reached without a depth change)
        the policy escalates straight to the ``evict_after`` backstop — a
        stage owner runs every microbatch through its stage, so the
        microbatch share derate would shed no work for it.  Per-unit slowdown
        for stage owners is normalized by ``n_micro x stage depth``
        (share-independent) — a deliberately deeper stage is not "slow" for
        taking proportionally longer.
    on_rebalance / on_evict / on_restage:
        Actuator callbacks: ``on_rebalance(host, weight, report)`` after a
        weight change, ``on_evict(host, report)`` after an eviction (where the
        launcher rebuilds the mesh), ``on_restage(host, stage, depths,
        report)`` after a stage-boundary move (where the launcher re-packs
        stage parameters via :meth:`~repro.dist.pipeline.StagePlan.pack`).
    evict_barrier:
        Optional checkpoint-before-evict gate: ``evict_barrier(step, report)``
        must make the fleet safe to shrink (durably checkpoint) and return the
        :class:`ControlAction` describing what it did — recorded *before* the
        ``evict`` row — or ``None`` to veto.  On a veto the eviction is
        deferred, not cancelled: the streak is left growing, so the next
        flagged check retries the barrier.  Typically
        :meth:`repro.adapt.checkpoint.CheckpointControl.evict_barrier`.
    reshard_gate:
        Optional payback gate, consulted *before* the barrier:
        ``reshard_gate(step, host, report, slowdown)`` returns ``None`` when
        the projected win of shedding the host covers the re-shard cost (the
        eviction proceeds), or a :class:`ControlAction` (an
        ``ADAPT/fleet::defer_reshard`` row) recording why the move is not
        worth it — the eviction is skipped, the action is recorded, and the
        streak keeps growing so the gate re-evaluates every flagged check.
        Typically :meth:`repro.fleet.payback.PaybackPolicy.evict_gate`.
    """

    def __init__(
        self,
        detector: StragglerDetector,
        plan: MicrobatchPlan,
        *,
        check_every: int = 1,
        confirm_after: int = 1,
        evict_after: int = 4,
        min_weight: float = 0.25,
        rel_tol: float = 0.05,
        local_feed: tuple[int, str] | None = None,
        stage_plan: StagePlan | None = None,
        stage_for_host: Mapping[int, int] | None = None,
        on_rebalance: Callable[[int, float, StragglerReport], None] | None = None,
        on_evict: Callable[[int, StragglerReport], None] | None = None,
        on_restage: Callable[[int, int, dict[int, int], StragglerReport], None] | None = None,
        evict_barrier: Callable[[int, StragglerReport], ControlAction | None] | None = None,
        reshard_gate: Callable[[int, int, StragglerReport, float], ControlAction | None]
        | None = None,
    ) -> None:
        if check_every < 1:
            raise ValueError(f"check_every must be >= 1, got {check_every}")
        if confirm_after < 1:
            raise ValueError(f"confirm_after must be >= 1, got {confirm_after}")
        if evict_after < confirm_after:
            raise ValueError(
                f"evict_after ({evict_after}) must be >= confirm_after ({confirm_after})"
            )
        if not 0.0 < min_weight <= 1.0:
            raise ValueError(f"min_weight must be in (0, 1], got {min_weight}")
        self.name = "stragglers"
        self.detector = detector
        self.plan = plan
        self.check_every = check_every
        self.confirm_after = confirm_after
        self.evict_after = evict_after
        self.min_weight = min_weight
        self.rel_tol = rel_tol
        if (stage_plan is None) != (stage_for_host is None):
            raise ValueError(
                "stage_plan and stage_for_host must be passed together"
            )
        self.local_feed = local_feed
        self.stage_plan = stage_plan
        self.stage_for_host = dict(stage_for_host) if stage_for_host else {}
        self.on_rebalance = on_rebalance
        self.on_evict = on_evict
        self.on_restage = on_restage
        self.evict_barrier = evict_barrier
        self.reshard_gate = reshard_gate
        #: evictions vetoed by the barrier (save not yet durable) — each one
        #: is a deferral, retried on the next flagged check
        self.deferred_evictions = 0
        #: evictions skipped by the payback gate (projected win under the
        #: re-shard cost) — each skip is its own recorded defer_reshard row
        self.deferred_reshards = 0
        self.channels = tuple(
            f"DIST/host{h}::step" for h in range(detector.n_hosts)
        )
        self._streak: dict[int, int] = {}
        #: each host's weight at registration — the ceiling restores climb
        #: back to (plans may assign above-1.0 weights to bigger hosts)
        self._full_weight: dict[int, float] = dict(plan.weights)
        #: each stage's weight at registration — the restage derate baseline
        self._full_stage_weight: dict[int, float] = (
            dict(stage_plan.weights) if stage_plan is not None else {}
        )

    # -- Controller protocol ------------------------------------------------------
    def control(
        self, step: int, measurements: Mapping[str, Measurement]
    ) -> list[ControlAction]:
        detector = self.detector
        if self.local_feed is not None:
            host, timer_name = self.local_feed
            detector.observe_timer(host, timer_name)
        detector.drain_transport()
        if (step + 1) % self.check_every != 0:
            return []
        report = detector.check(step)
        flagged = set(report.stragglers)
        for host in list(self._streak):
            if host not in flagged:
                self._streak[host] = 0
        # snapshot the work units the report's means were measured under:
        # acting on the first of two simultaneous stragglers changes every
        # host's live share (or stage depth), and dividing the second host's
        # (old-assignment) mean by its new units would misjudge it as
        # assignment-induced
        shares = self._work_units(self.plan.shares())
        actions: list[ControlAction] = []
        for host in sorted(flagged):
            self._streak[host] = self._streak.get(host, 0) + 1
            actions.extend(self._respond(step, host, report, shares))
        for host in self.plan.hosts:
            if host not in flagged:
                if self._owns_stage(host):
                    action = self._restore_stage(step, host, report, shares)
                else:
                    action = self._restore(step, host, report, shares)
                if action is not None:
                    actions.append(action)
        return actions

    # -- elastic membership --------------------------------------------------------
    def register_host(self, host: int, stage: int | None = None) -> None:
        """Adopt a newly admitted host (elastic membership join).

        The membership layer grows the shared plan first (``MicrobatchPlan.
        retarget`` in place — the newcomer enters at the carried mean weight);
        this call brings the response's own state into lockstep: the weight
        ceiling registers, the detector grows a window, the trigger-channel
        surface extends, and (optionally) the host takes a pipeline stage.
        """
        host = int(host)
        if host not in self.plan.weights:
            raise ValueError(f"host {host} not in the plan; grow the plan first")
        self._full_weight[host] = self.plan.weights[host]
        self.detector.add_host(host)
        self._streak[host] = 0
        self.channels = tuple(
            sorted(set(self.channels) | {f"DIST/host{host}::step"})
        )
        if stage is not None and self.stage_plan is not None:
            self.stage_for_host[host] = int(stage)

    def remove_host(self, host: int) -> None:
        """Shed a departing host without judging it (heartbeat-expiry leaves,
        operator drains): the same plan/detector/transport/stage bookkeeping
        as a straggler eviction, minus the ``evict`` action row and the
        ``on_evict`` callback — the caller owns the departure's journal."""
        host = int(host)
        self.plan.evict(host)
        self.detector.evict(host)
        self._streak.pop(host, None)
        self._full_weight.pop(host, None)
        self._drop_orphan_stage(host)

    def _drop_orphan_stage(self, host: int) -> None:
        """An evicted host's stage must not stay in the StagePlan: depths()
        would keep apportioning layers to a rank nobody runs.  Drop the stage
        (its layers re-apportion among survivors) unless another host still
        owns it."""
        stage = self.stage_for_host.pop(host, None)
        if (
            self.stage_plan is not None
            and stage is not None
            and stage in self.stage_plan.weights
            and stage not in self.stage_for_host.values()
            and len(self.stage_plan.weights) > 1
        ):
            del self.stage_plan.weights[stage]
            self._full_stage_weight.pop(stage, None)

    # -- policy -------------------------------------------------------------------
    def _owns_stage(self, host: int) -> bool:
        return (
            self.stage_plan is not None
            and self.stage_for_host.get(host) in self.stage_plan.weights
        )

    def _work_units(self, shares: Mapping[int, float]) -> dict[int, float]:
        """{host: work units per step}.

        For a data-parallel host this is its microbatch share.  A host that
        owns a pipeline stage runs *every* microbatch through its stage
        regardless of share, so its work is ``n_micro x stage depth`` —
        share-independent.  Normalizing a stage owner by its share would make
        a share derate (which moves no work for it) look like a slowdown and
        a small-share healthy host look like a straggler.
        """
        depths = self.stage_plan.depths() if self.stage_plan is not None else {}
        units: dict[int, float] = {}
        for h, s in shares.items():
            stage = self.stage_for_host.get(h)
            if stage in depths:
                units[h] = self.plan.n_micro * depths[stage]
            else:
                units[h] = s
        return units

    def _unit_slowdown(
        self, host: int, report: StragglerReport, shares: Mapping[int, float]
    ) -> float | None:
        """Per-work-unit slowdown vs the fleet's median per-unit time.

        The detector flags on *raw* step time — the right fleet-health signal,
        but it conflates "slow per unit of work" with "deliberately assigned
        more work" (a weight-2 host takes proportionally longer steps by
        design).  The response policy therefore normalizes by each host's
        work units (microbatch share x owned stage depth) before deciding, so
        only genuine per-unit slowness is ever acted on.  ``shares`` is the
        caller's per-check snapshot — the apportionment the report's means
        were measured under.
        """
        per_unit = {
            h: mean / shares[h]
            for h, mean in report.host_means.items()
            if shares.get(h)
        }
        if host not in per_unit:
            return None
        med = statistics.median(per_unit.values())
        if med <= 0.0:
            return None
        return per_unit[host] / med

    def _target_weight(self, host: int, slowdown: float) -> float:
        """Equilibrium weight: nominal capacity derated by per-unit slowdown."""
        full = self._full_weight.get(host, 1.0)
        return min(max(full / slowdown, self.min_weight), full)

    def _weight_dropping_share(self, host: int) -> float | None:
        """Largest probed weight >= ``min_weight`` that sheds one microbatch.

        The weight->share mapping is stepped (largest-remainder with a
        reserved minimum), so a host can sit at its equilibrium *weight*
        while rounding parks one extra microbatch on it.  Probing the actual
        apportionment separates that case (shed the microbatch) from true
        granularity exhaustion (``None``: nothing below ``min_weight`` moves
        the share — escalation is all that is left).  The plan is restored
        before returning; the loop is synchronous, so the in-place probe is
        not observable.
        """
        plan = self.plan
        current = plan.shares()[host]
        if current <= 1:
            return None
        saved = plan.weights[host]
        found = None
        probe = saved
        try:
            while probe > self.min_weight + _PROBE_FLOOR_EPS:
                probe = max(probe * _PROBE_DECAY, self.min_weight)
                plan.weights[host] = probe
                if plan.shares()[host] < current:
                    found = probe
                    break
        finally:
            plan.weights[host] = saved
        return found

    def _respond(
        self, step: int, host: int, report: StragglerReport, shares: Mapping[int, float]
    ) -> list[ControlAction]:
        plan = self.plan
        streak = self._streak[host]
        if streak < self.confirm_after:
            return []  # not yet confirmed: wait out transients
        weight = plan.weights.get(host)
        if weight is None:  # host not in this plan (already gone)
            return []
        slowdown = self._unit_slowdown(host, report, shares)
        if slowdown is None or slowdown <= self.detector.threshold:
            # the raw-step-time flag was share-induced, not per-unit slowness
            self._streak[host] = 0
            return []
        if self._owns_stage(host):
            # a stage owner's work is depth-bound: move its boundary; when
            # the boundary cannot move further, a share derate would shed no
            # work, so escalation goes straight to the eviction backstop
            restaged = self._try_restage(step, host, report, slowdown)
            if restaged is not None:
                return [restaged]
            if streak >= self.evict_after and len(plan.weights) > 1:
                return self._evict_with_barrier(step, host, report, slowdown)
            return []
        at_floor = weight <= self.min_weight * (1.0 + 1e-9)
        if (at_floor or streak >= self.evict_after) and len(plan.weights) > 1:
            return self._evict_with_barrier(step, host, report, slowdown)
        desired = self._target_weight(host, slowdown)
        if desired >= weight * (1.0 - self.rel_tol):
            # Weight already matches the degraded capacity, yet the host is
            # still raw-flagged.  Two distinct causes:
            #  - apportionment rounding parked one extra microbatch on the
            #    derated host -> shed it (a weight that actually drops the
            #    share exists);
            #  - share granularity is exhausted (already at the 1-microbatch
            #    minimum / weight floor) -> leave the streak growing, which
            #    is exactly the case the evict_after backstop exists for.
            shed = self._weight_dropping_share(host)
            if shed is None:
                return []
            desired = shed
        self._set_weight(host, desired, report)
        return [
            ControlAction(
                step=step,
                controller=self.name,
                trigger=f"DIST/host{host}::step",
                action="rebalance",
                detail={
                    "host": host,
                    "slowdown": round(slowdown, 3),
                    "weight": round(desired, 4),
                    "shares": plan.shares(),
                },
            )
        ]

    def _evict_with_barrier(
        self, step: int, host: int, report: StragglerReport, slowdown: float
    ) -> list[ControlAction]:
        """Run the checkpoint-before-evict barrier, then evict.

        Eviction is irreversible (the mesh rebuilds without the host), so the
        barrier's durable save must land *first*.  A ``None`` from the barrier
        vetoes this check's eviction — the streak is deliberately left intact,
        so the next flagged check retries; a wedged checkpoint path therefore
        delays shrinking the fleet instead of shrinking it unsafely.

        The payback gate runs even earlier: when the projected win of
        shedding the host does not cover the re-shard cost, the returned
        ``defer_reshard`` action is recorded *instead of* evicting (and
        instead of paying for a barrier save the fleet then would not use).
        The streak stays, so the gate re-evaluates on every flagged check —
        a host that keeps degrading eventually pays back and goes."""
        if self.reshard_gate is not None:
            deferred = self.reshard_gate(step, host, report, slowdown)
            if deferred is not None:
                self.deferred_reshards += 1
                return [deferred]
        if self.evict_barrier is not None:
            barrier_action = self.evict_barrier(step, report)
            if barrier_action is None:
                self.deferred_evictions += 1
                return []
            return [barrier_action, self._evict(step, host, report, slowdown)]
        return [self._evict(step, host, report, slowdown)]

    def _try_restage(
        self, step: int, host: int, report: StragglerReport, slowdown: float
    ) -> ControlAction | None:
        """Move the stage boundary off a slow stage owner, if it can move.

        Derates the owned stage's capacity weight to its equilibrium (nominal
        stage weight / per-unit slowdown, floored at ``min_weight``) and, when
        the equilibrium weight alone does not change the largest-remainder
        depth apportionment, probes smaller weights until one actually sheds
        a layer — mirroring :meth:`_weight_dropping_share` on the microbatch
        side.  Returns ``None`` when the host owns no stage, the stage is
        already at one layer, or no admissible weight moves the boundary —
        granularity exhausted: the caller escalates straight to the
        ``evict_after`` backstop (a share derate would shed no work for a
        depth-bound stage owner).
        """
        plan = self.stage_plan
        if plan is None:
            return None
        stage = self.stage_for_host.get(host)
        if stage is None or stage not in plan.weights:
            return None
        depths = plan.depths()
        if depths[stage] <= 1:
            return None  # boundary cannot move further
        full = self._full_stage_weight.get(stage, 1.0)
        saved = plan.weights[stage]
        candidate = min(max(full / slowdown, self.min_weight), saved)
        plan.weights[stage] = candidate
        # success means the straggler's OWN stage sheds a layer — rounding can
        # move a layer between two healthy stages while the slow one keeps its
        # full depth, and counting that as a restage would churn boundaries
        # and reset the escalation streak without making the host any faster
        shed = plan.depths()[stage] < depths[stage]
        while not shed and candidate > self.min_weight + _PROBE_FLOOR_EPS:
            # stepped apportionment: probe down for a weight that sheds a layer
            candidate = max(candidate * _PROBE_DECAY, self.min_weight)
            plan.weights[stage] = candidate
            shed = plan.depths()[stage] < depths[stage]
        if not shed:
            plan.weights[stage] = saved
            return None
        new_depths = plan.depths()
        # same stale-sample hygiene as a share change: the host's next
        # judgment must use samples measured under the new stage depth
        self.detector.reset_window(host)
        self._streak[host] = 0
        if self.on_restage is not None:
            self.on_restage(host, stage, new_depths, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="restage",
            detail={
                "host": host,
                "stage": stage,
                "slowdown": round(slowdown, 3),
                "weight": round(candidate, 4),
                "depths": new_depths,
            },
        )

    def _restore_stage(
        self, step: int, host: int, report: StragglerReport, shares: Mapping[int, float]
    ) -> ControlAction | None:
        """Give a restaged, now-healthy stage owner its layers back.

        The stage-side mirror of :meth:`_restore`: the stage weight climbs
        toward its registered full value by the same equilibrium rule
        (``full / per-unit slowdown``, capped at full), so a transient
        throttle never permanently parks layers on the healthy stages.  An
        action is only emitted when the climb actually moves a boundary; a
        sub-granularity weight climb is applied silently (the next checks
        keep climbing until a layer moves back or the ceiling is reached).
        Per-unit slowdown is depth-normalized, so a host that just regained a
        layer is not re-judged slow merely for running more layers.
        """
        plan = self.stage_plan
        stage = self.stage_for_host.get(host)
        if plan is None or stage not in plan.weights or not shares.get(host):
            return None
        weight = plan.weights[stage]
        full = self._full_stage_weight.get(stage, 1.0)
        if weight >= full:
            return None
        slowdown = self._unit_slowdown(host, report, shares)
        if slowdown is None or slowdown <= 0.0:
            return None
        desired = min(max(full / slowdown, self.min_weight), full)
        if desired <= weight * (1.0 + self.rel_tol):
            return None  # not measurably under-loaded: leave it
        depths_before = plan.depths()
        plan.weights[stage] = desired
        new_depths = plan.depths()
        if new_depths == depths_before:
            return None  # weight climbed, boundary unchanged: no action yet
        self.detector.reset_window(host)
        self._streak[host] = 0
        if self.on_restage is not None:
            self.on_restage(host, stage, new_depths, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="restore",
            detail={
                "host": host,
                "stage": stage,
                "slowdown": round(slowdown, 3),
                "weight": round(desired, 4),
                "depths": new_depths,
            },
        )

    def _restore(
        self, step: int, host: int, report: StragglerReport, shares: Mapping[int, float]
    ) -> ControlAction | None:
        """Give a derated, now-healthy host its weight back (same equilibrium
        rule as rebalance, capped at the host's original weight)."""
        weight = self.plan.weights.get(host)
        if weight is None or not shares.get(host):
            return None
        full = self._full_weight.get(host, 1.0)
        if weight >= full:
            return None
        slowdown = self._unit_slowdown(host, report, shares)
        if slowdown is None or slowdown <= 0.0:
            return None
        desired = self._target_weight(host, slowdown)
        if desired <= weight * (1.0 + self.rel_tol):
            return None  # not measurably under-loaded: leave it
        # Anti-oscillation: a still-unit-slow host sitting one granularity
        # step below a share that re-flags it must not ping-pong
        # shed -> restore every check — predict the step time at the restored
        # share and stay put if it would immediately re-flag.  Hosts whose
        # per-unit time is healthy are exempt: their raw flags are
        # share-induced (deliberately heavy hosts) and filtered in _respond.
        if slowdown > self.detector.threshold:
            saved = self.plan.weights[host]
            self.plan.weights[host] = desired
            try:
                new_units = self.plan.shares()[host]
            finally:
                self.plan.weights[host] = saved
            unit_seconds = report.host_means[host] / shares[host]
            predicted = unit_seconds * new_units
            fleet_median = statistics.median(report.host_means.values())
            if fleet_median > 0.0 and predicted > self.detector.threshold * fleet_median:
                return None
        self._set_weight(host, desired, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="restore",
            detail={
                "host": host,
                "slowdown": round(slowdown, 3),
                "weight": round(desired, 4),
                "shares": self.plan.shares(),
            },
        )

    def _set_weight(self, host: int, weight: float, report: StragglerReport) -> None:
        """Apply a weight change; stale-sample hygiene lives here.  The
        detector window and the streak are reset so the host's *next*
        judgment uses only samples measured under the new assignment."""
        self.plan.set_weight(host, weight)
        self.detector.reset_window(host)
        self._streak[host] = 0
        if self.on_rebalance is not None:
            self.on_rebalance(host, weight, report)

    def _evict(
        self, step: int, host: int, report: StragglerReport, slowdown: float
    ) -> ControlAction:
        self.remove_host(host)
        if self.on_evict is not None:
            self.on_evict(host, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="evict",
            detail={
                "host": host,
                "slowdown": round(slowdown, 3),
                "survivors": self.plan.hosts,
                "shares": self.plan.shares(),
            },
        )
