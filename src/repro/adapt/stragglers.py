"""Straggler response: rebalance microbatch shares, then evict — the paper's
"dynamically adapt itself to a changing environment" loop closed at fleet
scale (cf. the Cactus Worm migration experiments, Allen et al. cs/0108001:
measure, decide, migrate).

:class:`StragglerResponse` sits between the measurement reduction
(:class:`~repro.dist.stragglers.StragglerDetector`, fed by all hosts through
an injectable transport) and two actuators:

* **rebalance** — set the flagged host's weight in the fleet's
  :class:`~repro.dist.pipeline.MicrobatchPlan` to its equilibrium (nominal
  weight / per-microbatch slowdown, floored at ``min_weight``), so its share
  of the pipelined microbatches matches its degraded capacity.  Slowdown is
  *share-normalized*: a host deliberately provisioned with a larger weight is
  not "slow" merely for taking proportionally longer steps;
* **restore** — the inverse: a derated host whose per-unit time is back in
  line (the slowdown was transient — a noisy neighbor, thermal throttling
  that cleared) earns its weight back by the same equilibrium rule, capped at
  its *original* weight, so a one-off hiccup never permanently costs the
  fleet capacity;
* **evict** — when a host stays flagged at the minimum weight (it is too slow
  to be worth its guaranteed share) or keeps getting flagged past the streak
  backstop, remove it: from the plan, from the detector's median, and from
  the transport, then hand the host to ``on_evict`` so the launcher rebuilds
  its mesh (:func:`repro.dist.meshutil.remove_host`).

After every weight change the host's detector window and streak are reset:
samples measured under the old assignment no longer describe the host, and
judging the new assignment on stale samples compounds derates into spurious
evictions of already-fixed hosts.

Every decision is returned as a :class:`ControlAction` so the control loop
records it in the ``ADAPT/`` log with the triggering ``DIST/host{h}::step``
channel.
"""

from __future__ import annotations

import statistics
from collections.abc import Callable, Mapping

from ..dist.pipeline import MicrobatchPlan
from ..dist.stragglers import StragglerDetector, StragglerReport
from .controller import ControlAction, Measurement

__all__ = ["StragglerResponse"]


class StragglerResponse:
    """Rebalance-then-evict policy over a straggler detector and a plan.

    Parameters
    ----------
    detector:
        The cross-host reduction point.  The controller drains its transport
        and runs :meth:`~repro.dist.stragglers.StragglerDetector.check` every
        ``check_every`` polls.
    plan:
        The fleet's microbatch assignment to act on.
    check_every:
        Polls between fleet checks (mirrors a launcher checking every N
        steps; the check fires on polls where ``(step + 1) % check_every == 0``).
    confirm_after:
        Consecutive flagged checks before the first rebalance — one flagged
        window can be a transient (GC pause, kernel hiccup); a confirmed
        straggler is one that stays slow.
    evict_after:
        Consecutive flagged checks after which the host is evicted regardless
        of weight.  The streak resets on every weight change, whenever the
        flag turns out share-induced (per-unit time fine), and whenever the
        raw flag clears — so escalation only counts checks where the host was
        genuinely slow and no rebalance could absorb it (already at the
        weight floor, or share granularity exhausted).
    min_weight:
        Floor for a rebalanced host's weight.  A host flagged *at* the floor
        has already been derated as far as policy allows and is evicted.
    rel_tol:
        Minimum relative weight change worth acting on (hysteresis guard
        against churning the plan for measurement noise).
    local_feed:
        Optional ``(host, timer_name)``: each poll additionally samples this
        process's own step timer straight out of the timer database — the
        single-process path the training launcher uses alongside (or instead
        of) a transport.
    on_rebalance / on_evict:
        Actuator callbacks: ``on_rebalance(host, weight, report)`` after a
        weight change, ``on_evict(host, report)`` after an eviction (where the
        launcher rebuilds the mesh).
    """

    def __init__(
        self,
        detector: StragglerDetector,
        plan: MicrobatchPlan,
        *,
        check_every: int = 1,
        confirm_after: int = 1,
        evict_after: int = 4,
        min_weight: float = 0.25,
        rel_tol: float = 0.05,
        local_feed: tuple[int, str] | None = None,
        on_rebalance: Callable[[int, float, StragglerReport], None] | None = None,
        on_evict: Callable[[int, StragglerReport], None] | None = None,
    ) -> None:
        if check_every < 1:
            raise ValueError(f"check_every must be >= 1, got {check_every}")
        if confirm_after < 1:
            raise ValueError(f"confirm_after must be >= 1, got {confirm_after}")
        if evict_after < confirm_after:
            raise ValueError(
                f"evict_after ({evict_after}) must be >= confirm_after ({confirm_after})"
            )
        if not 0.0 < min_weight <= 1.0:
            raise ValueError(f"min_weight must be in (0, 1], got {min_weight}")
        self.name = "stragglers"
        self.detector = detector
        self.plan = plan
        self.check_every = check_every
        self.confirm_after = confirm_after
        self.evict_after = evict_after
        self.min_weight = min_weight
        self.rel_tol = rel_tol
        self.local_feed = local_feed
        self.on_rebalance = on_rebalance
        self.on_evict = on_evict
        self.channels = tuple(
            f"DIST/host{h}::step" for h in range(detector.n_hosts)
        )
        self._streak: dict[int, int] = {}
        #: each host's weight at registration — the ceiling restores climb
        #: back to (plans may assign above-1.0 weights to bigger hosts)
        self._full_weight: dict[int, float] = dict(plan.weights)

    # -- Controller protocol ------------------------------------------------------
    def control(
        self, step: int, measurements: Mapping[str, Measurement]
    ) -> list[ControlAction]:
        detector = self.detector
        if self.local_feed is not None:
            host, timer_name = self.local_feed
            detector.observe_timer(host, timer_name)
        detector.drain_transport()
        if (step + 1) % self.check_every != 0:
            return []
        report = detector.check(step)
        flagged = set(report.stragglers)
        for host in list(self._streak):
            if host not in flagged:
                self._streak[host] = 0
        # snapshot the shares the report's means were measured under: acting
        # on the first of two simultaneous stragglers changes every host's
        # live share, and dividing the second host's (old-share) mean by its
        # new share would misjudge it as share-induced
        shares = self.plan.shares()
        actions: list[ControlAction] = []
        for host in sorted(flagged):
            self._streak[host] = self._streak.get(host, 0) + 1
            action = self._respond(step, host, report, shares)
            if action is not None:
                actions.append(action)
        for host in self.plan.hosts:
            if host not in flagged:
                action = self._restore(step, host, report, shares)
                if action is not None:
                    actions.append(action)
        return actions

    # -- policy -------------------------------------------------------------------
    def _unit_slowdown(
        self, host: int, report: StragglerReport, shares: Mapping[int, int]
    ) -> float | None:
        """Per-microbatch slowdown vs the fleet's median per-microbatch time.

        The detector flags on *raw* step time — the right fleet-health signal,
        but it conflates "slow per unit of work" with "deliberately assigned
        more work" (a weight-2 host takes proportionally longer steps by
        design).  The response policy therefore normalizes by each host's
        share before deciding, so only genuine per-unit slowness is ever
        acted on.  ``shares`` is the caller's per-check snapshot — the
        apportionment the report's means were measured under.
        """
        per_unit = {
            h: mean / shares[h]
            for h, mean in report.host_means.items()
            if shares.get(h)
        }
        if host not in per_unit:
            return None
        med = statistics.median(per_unit.values())
        if med <= 0.0:
            return None
        return per_unit[host] / med

    def _target_weight(self, host: int, slowdown: float) -> float:
        """Equilibrium weight: nominal capacity derated by per-unit slowdown."""
        full = self._full_weight.get(host, 1.0)
        return min(max(full / slowdown, self.min_weight), full)

    def _weight_dropping_share(self, host: int) -> float | None:
        """Largest probed weight >= ``min_weight`` that sheds one microbatch.

        The weight->share mapping is stepped (largest-remainder with a
        reserved minimum), so a host can sit at its equilibrium *weight*
        while rounding parks one extra microbatch on it.  Probing the actual
        apportionment separates that case (shed the microbatch) from true
        granularity exhaustion (``None``: nothing below ``min_weight`` moves
        the share — escalation is all that is left).  The plan is restored
        before returning; the loop is synchronous, so the in-place probe is
        not observable.
        """
        plan = self.plan
        current = plan.shares()[host]
        if current <= 1:
            return None
        saved = plan.weights[host]
        found = None
        probe = saved
        try:
            while probe > self.min_weight + 1e-12:
                probe = max(probe * 0.75, self.min_weight)
                plan.weights[host] = probe
                if plan.shares()[host] < current:
                    found = probe
                    break
        finally:
            plan.weights[host] = saved
        return found

    def _respond(
        self, step: int, host: int, report: StragglerReport, shares: Mapping[int, int]
    ) -> ControlAction | None:
        plan = self.plan
        streak = self._streak[host]
        if streak < self.confirm_after:
            return None  # not yet confirmed: wait out transients
        weight = plan.weights.get(host)
        if weight is None:  # host not in this plan (already gone)
            return None
        slowdown = self._unit_slowdown(host, report, shares)
        if slowdown is None or slowdown <= self.detector.threshold:
            # the raw-step-time flag was share-induced, not per-unit slowness
            self._streak[host] = 0
            return None
        at_floor = weight <= self.min_weight * (1.0 + 1e-9)
        if (at_floor or streak >= self.evict_after) and len(plan.weights) > 1:
            return self._evict(step, host, report, slowdown)
        desired = self._target_weight(host, slowdown)
        if desired >= weight * (1.0 - self.rel_tol):
            # Weight already matches the degraded capacity, yet the host is
            # still raw-flagged.  Two distinct causes:
            #  - apportionment rounding parked one extra microbatch on the
            #    derated host -> shed it (a weight that actually drops the
            #    share exists);
            #  - share granularity is exhausted (already at the 1-microbatch
            #    minimum / weight floor) -> leave the streak growing, which
            #    is exactly the case the evict_after backstop exists for.
            shed = self._weight_dropping_share(host)
            if shed is None:
                return None
            desired = shed
        self._set_weight(host, desired, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="rebalance",
            detail={
                "host": host,
                "slowdown": round(slowdown, 3),
                "weight": round(desired, 4),
                "shares": plan.shares(),
            },
        )

    def _restore(
        self, step: int, host: int, report: StragglerReport, shares: Mapping[int, int]
    ) -> ControlAction | None:
        """Give a derated, now-healthy host its weight back (same equilibrium
        rule as rebalance, capped at the host's original weight)."""
        weight = self.plan.weights.get(host)
        if weight is None or not shares.get(host):
            return None
        full = self._full_weight.get(host, 1.0)
        if weight >= full:
            return None
        slowdown = self._unit_slowdown(host, report, shares)
        if slowdown is None or slowdown <= 0.0:
            return None
        desired = self._target_weight(host, slowdown)
        if desired <= weight * (1.0 + self.rel_tol):
            return None  # not measurably under-loaded: leave it
        # Anti-oscillation: a still-unit-slow host sitting one granularity
        # step below a share that re-flags it must not ping-pong
        # shed -> restore every check — predict the step time at the restored
        # share and stay put if it would immediately re-flag.  Hosts whose
        # per-unit time is healthy are exempt: their raw flags are
        # share-induced (deliberately heavy hosts) and filtered in _respond.
        if slowdown > self.detector.threshold:
            saved = self.plan.weights[host]
            self.plan.weights[host] = desired
            try:
                new_share = self.plan.shares()[host]
            finally:
                self.plan.weights[host] = saved
            unit_seconds = report.host_means[host] / shares[host]
            predicted = unit_seconds * new_share
            fleet_median = statistics.median(report.host_means.values())
            if fleet_median > 0.0 and predicted > self.detector.threshold * fleet_median:
                return None
        self._set_weight(host, desired, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="restore",
            detail={
                "host": host,
                "slowdown": round(slowdown, 3),
                "weight": round(desired, 4),
                "shares": self.plan.shares(),
            },
        )

    def _set_weight(self, host: int, weight: float, report: StragglerReport) -> None:
        """Apply a weight change; stale-sample hygiene lives here.  The
        detector window and the streak are reset so the host's *next*
        judgment uses only samples measured under the new assignment."""
        self.plan.set_weight(host, weight)
        self.detector.reset_window(host)
        self._streak[host] = 0
        if self.on_rebalance is not None:
            self.on_rebalance(host, weight, report)

    def _evict(
        self, step: int, host: int, report: StragglerReport, slowdown: float
    ) -> ControlAction:
        self.plan.evict(host)
        self.detector.evict(host)
        self._streak.pop(host, None)
        if self.on_evict is not None:
            self.on_evict(host, report)
        return ControlAction(
            step=step,
            controller=self.name,
            trigger=f"DIST/host{host}::step",
            action="evict",
            detail={
                "host": host,
                "slowdown": round(slowdown, 3),
                "survivors": self.plan.hosts,
                "shares": self.plan.shares(),
            },
        )
