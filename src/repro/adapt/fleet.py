"""A CPU-only simulated multi-host fleet for closing the adapt loop in tests.

Real deployments run one process per host: each publishes its step walltime
through a collective-backed transport, one reducing process runs the control
loop, and eviction rebuilds the device mesh
(:func:`repro.dist.meshutil.remove_host`).  :class:`SimulatedFleet` compresses
that topology into one process so the whole measure → decide → rebalance →
evict → rebuild chain is exercisable in CI:

* every simulated host "executes" its :class:`~repro.dist.pipeline.MicrobatchPlan`
  share per fleet step; its step walltime is *synthetic* — per-microbatch cost
  x assigned share, no sleeping — so tests are fast and deterministic;
* the walltimes travel through the same injectable
  :class:`~repro.dist.stragglers.LocalTransport` a real launcher would back
  with an all-gather;
* eviction triggers a mesh rebuild through :mod:`repro.dist.meshutil` (each
  surviving host gets a fresh local mesh; ``mesh_generation`` counts
  rebuilds), mirroring what a launcher does with ``remove_host`` on a real
  multi-host mesh;
* optionally (``run_pipeline=True``) each host really feeds its share through
  :func:`~repro.dist.pipeline.gpipe_forward` on its local mesh, proving the
  rebalanced assignment produces working pipeline calls end to end.

Inject a slowdown with :meth:`slow_host`, drive steps with :meth:`run_step`,
and read convergence off :meth:`spread`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.timers import TimerDB, timer_db
from ..dist.meshutil import local_mesh
from ..dist.pipeline import MicrobatchPlan, gpipe_forward
from ..dist.stragglers import LocalTransport, StragglerDetector
from .stragglers import StragglerResponse

__all__ = ["SimulatedFleet"]


class SimulatedFleet:
    """n simulated hosts, a shared microbatch plan, and a straggler responder.

    The fleet owns the full wiring: transport -> detector -> response
    controller; register :attr:`controller` on a
    :class:`~repro.adapt.controller.ControlLoop` and alternate
    ``fleet.run_step(i)`` / ``loop.poll(i)``.
    """

    def __init__(
        self,
        n_hosts: int,
        n_micro: int,
        *,
        per_micro_seconds: float = 1.0,
        window: int = 4,
        threshold: float = 1.5,
        check_every: int = 1,
        confirm_after: int = 1,
        evict_after: int = 4,
        min_weight: float = 0.25,
        db: TimerDB | None = None,
        run_pipeline: bool = False,
        micro_batch: int = 2,
        feature_dim: int = 4,
    ) -> None:
        self.db = db if db is not None else timer_db()
        self.transport = LocalTransport()
        self.plan = MicrobatchPlan.equal(range(n_hosts), n_micro)
        self.detector = StragglerDetector(
            n_hosts,
            window=window,
            threshold=threshold,
            transport=self.transport,
            db=self.db,
        )
        self.controller = StragglerResponse(
            self.detector,
            self.plan,
            check_every=check_every,
            confirm_after=confirm_after,
            evict_after=evict_after,
            min_weight=min_weight,
            on_evict=self._rebuild_meshes,
        )
        #: per-microbatch execution cost of each host (seconds, synthetic)
        self.costs: dict[int, float] = {h: float(per_micro_seconds) for h in range(n_hosts)}
        self.run_pipeline = run_pipeline
        self.micro_batch = micro_batch
        self.feature_dim = feature_dim
        self.evicted: list[int] = []
        self.mesh_generation = 0
        self.meshes: dict[int, object] = {}
        self.last_step_seconds: dict[int, float] = {}
        self._rebuild_meshes(host=None, report=None)

    # -- environment --------------------------------------------------------------
    def slow_host(self, host: int, factor: float) -> None:
        """Inject a slowdown: host's per-microbatch cost multiplies by
        ``factor`` (a degraded node, thermal throttling, a noisy neighbor)."""
        if host not in self.costs:
            raise ValueError(f"unknown host {host}")
        self.costs[host] *= float(factor)

    # -- one fleet step ------------------------------------------------------------
    def run_step(self, step: int) -> dict[int, float]:
        """Execute one fleet step: every active host runs its share and
        publishes its (synthetic) walltime through the transport.  Returns
        {host: step seconds}."""
        shares = self.plan.shares()
        seconds: dict[int, float] = {}
        for host, share in shares.items():
            if self.run_pipeline:
                self._run_host_pipeline(host, share)
            seconds[host] = self.costs[host] * share
            self.transport.publish(host, seconds[host])
        self.last_step_seconds = seconds
        return seconds

    def _run_host_pipeline(self, host: int, share: int) -> None:
        """Really push the host's microbatch share through gpipe_forward on
        its local mesh (1 stage, tiny tensors) — correctness ballast for the
        simulated timing."""
        mesh = self.meshes[host]
        stage_w = jnp.ones((1, self.feature_dim), jnp.float32) * 0.5
        x = jnp.ones((share * self.micro_batch, self.feature_dim), jnp.float32)
        y = gpipe_forward(
            lambda w, a: a * w,
            stage_w,
            x,
            mesh=mesh,
            axis="pod",
            n_micro=share,
        )
        jax.block_until_ready(y)
        if y.shape != x.shape:
            raise AssertionError(f"pipeline shape drift: {y.shape} != {x.shape}")

    # -- queries -------------------------------------------------------------------
    def active_hosts(self) -> list[int]:
        return self.plan.hosts

    def spread(self) -> float:
        """Max - min step seconds across active hosts at the last step — the
        cross-host imbalance the control loop is trying to shrink."""
        vals = [
            s for h, s in self.last_step_seconds.items() if h in self.plan.weights
        ]
        if not vals:
            return 0.0
        return max(vals) - min(vals)

    # -- eviction actuator -----------------------------------------------------------
    def _rebuild_meshes(self, host, report) -> None:
        """(Re)build every active host's local mesh — the simulated analogue
        of ``remove_host`` on a real fleet-spanning mesh.  Called at
        construction and again by the response controller on every eviction."""
        if host is not None:
            self.evicted.append(host)
            self.meshes.pop(host, None)
            self.costs.pop(host, None)
            self.last_step_seconds.pop(host, None)
            self.mesh_generation += 1
        self.meshes = {h: local_mesh((1,), ("pod",)) for h in self.plan.hosts}
