"""A CPU-only simulated multi-host fleet for closing the adapt loop in tests.

Real deployments run one process per host: each publishes its step walltime
through a collective-backed transport, one reducing process runs the control
loop, and eviction rebuilds the device mesh
(:func:`repro.dist.meshutil.remove_host`).  :class:`SimulatedFleet` compresses
that topology into one process so the whole measure → decide → rebalance →
evict → rebuild chain is exercisable in CI:

* every simulated host "executes" its :class:`~repro.dist.pipeline.MicrobatchPlan`
  share per fleet step; its step walltime is *synthetic* — per-microbatch cost
  x assigned share, no sleeping — so tests are fast and deterministic;
* the walltimes travel through the same injectable
  :class:`~repro.dist.stragglers.LocalTransport` a real launcher would back
  with an all-gather;
* eviction triggers a mesh rebuild through :mod:`repro.dist.meshutil` (each
  surviving host gets a fresh local mesh; ``mesh_generation`` counts
  rebuilds), mirroring what a launcher does with ``remove_host`` on a real
  multi-host mesh;
* optionally (``run_pipeline=True``) each host really feeds its share through
  :func:`~repro.dist.pipeline.gpipe_forward` on its local mesh, proving the
  rebalanced assignment produces working pipeline calls end to end;
* with ``n_layers > 0`` the fleet becomes a **pipeline fleet**: host ``h``
  owns pipeline stage ``h`` of a shared :class:`~repro.dist.pipeline.StagePlan`
  and its synthetic step time scales with its *stage depth* — so the straggler
  response answers a slow stage owner by moving the stage boundary
  (``restage``), and ``run_pipeline=True`` executes the restaged boundaries
  through a real 1F1B :class:`~repro.dist.pipeline.PipelineStep` (packed
  params + slot mask) to prove the new split computes.

Inject a slowdown with :meth:`slow_host`, drive steps with :meth:`run_step`,
and read convergence off :meth:`spread`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.timers import TimerDB, timer_db
from ..dist.meshutil import local_mesh
from ..dist.pipeline import (
    MicrobatchPlan,
    PipelineStep,
    StagePlan,
    gpipe_forward,
)
from ..dist.stragglers import LocalTransport, StragglerDetector
from .stragglers import StragglerResponse

__all__ = ["SimulatedFleet"]


class SimulatedFleet:
    """n simulated hosts, a shared microbatch plan, and a straggler responder.

    The fleet owns the full wiring: transport -> detector -> response
    controller; register :attr:`controller` on a
    :class:`~repro.adapt.controller.ControlLoop` and alternate
    ``fleet.run_step(i)`` / ``loop.poll(i)``.
    """

    def __init__(
        self,
        n_hosts: int,
        n_micro: int,
        *,
        per_micro_seconds: float = 1.0,
        window: int = 4,
        threshold: float = 1.5,
        check_every: int = 1,
        confirm_after: int = 1,
        evict_after: int = 4,
        min_weight: float = 0.25,
        db: TimerDB | None = None,
        run_pipeline: bool = False,
        micro_batch: int = 2,
        feature_dim: int = 4,
        n_layers: int = 0,
    ) -> None:
        self.db = db if db is not None else timer_db()
        self.transport = LocalTransport()
        self.plan = MicrobatchPlan.equal(range(n_hosts), n_micro)
        #: pipeline mode: host h owns stage h of a shared layer stack
        self.stage_plan = (
            StagePlan.equal(range(n_hosts), n_layers) if n_layers > 0 else None
        )
        self.detector = StragglerDetector(
            n_hosts,
            window=window,
            threshold=threshold,
            transport=self.transport,
            db=self.db,
        )
        self.controller = StragglerResponse(
            self.detector,
            self.plan,
            check_every=check_every,
            confirm_after=confirm_after,
            evict_after=evict_after,
            min_weight=min_weight,
            stage_plan=self.stage_plan,
            stage_for_host=(
                {h: h for h in range(n_hosts)} if self.stage_plan else None
            ),
            on_evict=self._rebuild_meshes,
            on_restage=self._on_restage,
        )
        #: per-microbatch execution cost of each host (seconds, synthetic)
        self.costs: dict[int, float] = {h: float(per_micro_seconds) for h in range(n_hosts)}
        #: nominal (fault-free) costs — what :meth:`restore_host` returns to
        self.nominal_costs: dict[int, float] = dict(self.costs)
        self.run_pipeline = run_pipeline
        self.micro_batch = micro_batch
        self.feature_dim = feature_dim
        self.evicted: list[int] = []
        self.mesh_generation = 0
        #: restage actions applied: [(host, stage, depths)]
        self.restages: list[tuple[int, int, dict[int, int]]] = []
        self.meshes: dict[int, object] = {}
        self.last_step_seconds: dict[int, float] = {}
        self._pipeline_step: PipelineStep | None = None
        self._layer_params: jax.Array | None = None
        self._rebuild_meshes(host=None, report=None)

    # -- environment --------------------------------------------------------------
    def slow_host(self, host: int, factor: float) -> None:
        """Inject a slowdown: host's per-microbatch cost multiplies by
        ``factor`` (a degraded node, thermal throttling, a noisy neighbor)."""
        if host not in self.costs:
            raise ValueError(f"unknown host {host}")
        self.costs[host] *= float(factor)

    def hang_host(self, host: int, factor: float = 1000.0) -> None:
        """Inject a (near-)hang: the host still answers the transport but its
        steps take ``factor``× nominal — a wedged accelerator or livelocked
        rank.  Finite on purpose: the reduction still sees samples, so the
        response policy (derate → evict backstop) is what ends the stall."""
        if host not in self.costs:
            raise ValueError(f"unknown host {host}")
        self.costs[host] = self.nominal_costs[host] * float(factor)

    def restore_host(self, host: int) -> None:
        """Clear injected degradation: cost returns to nominal (the fault —
        noisy neighbor, thermal throttle — passed)."""
        if host not in self.costs:
            raise ValueError(f"unknown host {host}")
        self.costs[host] = self.nominal_costs[host]

    # -- one fleet step ------------------------------------------------------------
    def run_step(self, step: int) -> dict[int, float]:
        """Execute one fleet step: every active host runs its assignment and
        publishes its (synthetic) walltime through the transport.  Returns
        {host: step seconds}.

        Data-parallel mode: a host's work is its microbatch share.  Pipeline
        mode (``n_layers > 0``): every microbatch traverses every stage, so a
        host's work is ``stage depth x n_micro`` — shifting a stage boundary
        (restage) is what changes its step time.
        """
        shares = self.plan.shares()
        depths = self.stage_plan.depths() if self.stage_plan is not None else {}
        if self.run_pipeline and self.stage_plan is not None:
            self._run_stage_pipeline()
        seconds: dict[int, float] = {}
        for host, share in shares.items():
            if self.stage_plan is not None:
                # the controller's map is authoritative (it prunes entries on
                # eviction); the fleet constructs it as host h -> stage h but
                # must not assume that identity here
                stage = self.controller.stage_for_host.get(host)
                work = depths.get(stage, 0) * self.plan.n_micro
            else:
                if self.run_pipeline:
                    self._run_host_pipeline(host, share)
                work = share
            seconds[host] = self.costs[host] * work
            self.transport.publish(host, seconds[host])
        self.last_step_seconds = seconds
        return seconds

    def _run_host_pipeline(self, host: int, share: int) -> None:
        """Really push the host's microbatch share through gpipe_forward on
        its local mesh (1 stage, tiny tensors) — correctness ballast for the
        simulated timing."""
        mesh = self.meshes[host]
        stage_w = jnp.ones((1, self.feature_dim), jnp.float32) * 0.5
        x = jnp.ones((share * self.micro_batch, self.feature_dim), jnp.float32)
        y = gpipe_forward(
            lambda w, a: a * w,
            stage_w,
            x,
            mesh=mesh,
            axis="pod",
            n_micro=share,
        )
        jax.block_until_ready(y)
        if y.shape != x.shape:
            raise AssertionError(f"pipeline shape drift: {y.shape} != {x.shape}")

    def _run_stage_pipeline(self) -> None:
        """Execute the current :class:`StagePlan` boundaries through a real
        1F1B step (packed params + slot mask on the local pod mesh) — proof
        that a restaged split still computes a loss and per-slot gradients."""
        plan = self.stage_plan
        assert plan is not None
        if self._layer_params is None:
            self._layer_params = (
                jnp.ones((plan.n_layers, self.feature_dim), jnp.float32) * 0.9
            )
            mesh = local_mesh((1,), ("pod",))
            self._pipeline_step = PipelineStep(
                lambda w, a: a * w,
                lambda y, t: jnp.mean((y - t) ** 2),
                mesh=mesh,
                axis="pod",
                n_micro=self.plan.n_micro,
            )
        packed, mask = plan.pack(self._layer_params)
        batch = self.plan.n_micro * self.micro_batch
        x = jnp.ones((batch, self.feature_dim), jnp.float32)
        loss, grads = self._pipeline_step(packed, x, x * 0.5, stage_mask=mask)
        jax.block_until_ready(loss)
        if grads.shape != packed.shape:
            raise AssertionError(
                f"pipeline grad shape drift: {grads.shape} != {packed.shape}"
            )

    # -- queries -------------------------------------------------------------------
    def active_hosts(self) -> list[int]:
        return self.plan.hosts

    def spread(self) -> float:
        """Max - min step seconds across active hosts at the last step — the
        cross-host imbalance the control loop is trying to shrink."""
        vals = [
            s for h, s in self.last_step_seconds.items() if h in self.plan.weights
        ]
        if not vals:
            return 0.0
        return max(vals) - min(vals)

    # -- restage actuator ------------------------------------------------------------
    def _on_restage(self, host, stage, depths, report) -> None:
        """Record a stage-boundary move.  The next :meth:`run_step` (and the
        next :meth:`_run_stage_pipeline` pack) picks the new depths up from
        the shared plan — the simulated analogue of a launcher re-packing
        stage parameters before its next pipelined step."""
        self.restages.append((host, stage, dict(depths)))

    # -- eviction actuator -----------------------------------------------------------
    def _rebuild_meshes(self, host, report) -> None:
        """(Re)build every active host's local mesh — the simulated analogue
        of ``remove_host`` on a real fleet-spanning mesh.  Called at
        construction and again by the response controller on every eviction."""
        if host is not None:
            self.evicted.append(host)
            self.meshes.pop(host, None)
            self.costs.pop(host, None)
            self.nominal_costs.pop(host, None)
            self.last_step_seconds.pop(host, None)
            self.mesh_generation += 1
        self.meshes = {h: local_mesh((1,), ("pod",)) for h in self.plan.hosts}
