"""AdaptCheck as a control-plane citizen.

:class:`CheckpointControl` adapts the pure, replayable
:class:`~repro.core.adaptive.AdaptiveCheckpointController` (paper Sec. 3.2)
onto the :class:`~repro.adapt.controller.Controller` protocol: each poll it
reads the accumulated checkpoint walltime out of the timer database, applies
any live-steered policy parameters from the param registry (paper Sec. 5), and
asks the inner controller for a decision.  Admissions surface as
``checkpoint`` actions in the ``ADAPT/`` log; the launcher's CHECKPOINT-bin
routine consumes the pending decision with :meth:`take_decision` and performs
the actual write, then reports back through :meth:`observe_checkpoint` so the
duration predictor keeps learning.

This replaces the inline decision block ``repro.launch.train`` used to carry:
the same policy now lives behind the same registry as every other adaptation.

With a durable-save routine bound (:meth:`CheckpointControl.bind_durable_save`)
the controller also serves as the fleet's **eviction barrier**: removing a host
rebuilds the mesh and re-apportions its work, so the last thing that should
happen *before* that irreversible step is a checkpoint that is known durable.
:meth:`evict_barrier` plugs into
:class:`~repro.adapt.stragglers.StragglerResponse` — an eviction only proceeds
once the save lands, and the save itself shows up in the ``ADAPT/`` log as a
``checkpoint``-controller ``before_evict`` row.
"""

from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable, Mapping

from ..core.adaptive import AdaptiveCheckpointController, AdaptiveCheckpointPolicy, Decision
from ..core.params import ParamRegistry
from .controller import ControlAction, Measurement

__all__ = ["CheckpointControl"]


class CheckpointControl:
    """Controller wrapping AdaptCheck; polls the checkpoint-write timer.

    Parameters
    ----------
    inner:
        The :class:`AdaptiveCheckpointController` holding policy + predictor
        (constructed by the caller so policies stay explicit and testable), or
        an :class:`AdaptiveCheckpointPolicy` to wrap in a fresh controller.
    ckpt_timer:
        Timer-DB channel accumulating checkpoint write walltime — the
        controller's trigger channel.
    clock:
        Monotonic time source (injectable for replay tests).
    registry / fraction_param / interval_param:
        When a registry is given, each poll re-reads the two steerable policy
        parameters and applies changes to the inner policy before deciding —
        live steering exactly as the training launcher did inline.
    """

    def __init__(
        self,
        inner: AdaptiveCheckpointController | AdaptiveCheckpointPolicy,
        *,
        ckpt_timer: str = "CHECKPOINT/adaptcheck::write",
        clock: Callable[[], float] = time.monotonic,
        registry: ParamRegistry | None = None,
        fraction_param: str = "ckpt.max_fraction",
        interval_param: str = "ckpt.max_interval_s",
    ) -> None:
        if isinstance(inner, AdaptiveCheckpointPolicy):
            inner = AdaptiveCheckpointController(inner)
        self.name = "adaptcheck"
        self.inner = inner
        self.ckpt_timer = ckpt_timer
        self.channels = (ckpt_timer,)
        self._clock = clock
        self._registry = registry
        self._fraction_param = fraction_param
        self._interval_param = interval_param
        self._pending: Decision | None = None
        #: durable-save routine for the eviction barrier; bound by the
        #: launcher (``bind_durable_save``) once a checkpoint manager exists
        self._durable_save: Callable[[int], float] | None = None
        #: barrier bookkeeping for summaries / tests
        self.barrier_saves = 0
        self.barrier_failures = 0

    # -- lifecycle ---------------------------------------------------------------
    def start_run(self, now: float | None = None) -> None:
        self.inner.start_run(self._clock() if now is None else now)

    def observe_checkpoint(self, seconds: float, nbytes: float = 0.0) -> None:
        """Report a completed write (feeds the predictor and the interval)."""
        self.inner.observe_checkpoint(self._clock(), seconds, nbytes)

    def take_decision(self) -> Decision | None:
        """Pop the decision made at the last poll (None when never polled)."""
        decision, self._pending = self._pending, None
        return decision

    # -- eviction barrier ---------------------------------------------------------
    def bind_durable_save(self, save_fn: Callable[[int], float]) -> None:
        """Bind the launcher's durable-save routine: ``save_fn(step)`` must
        write a checkpoint at ``step`` and *block until it is durable on
        disk* (manager ``save`` + ``wait``), returning the write seconds."""
        self._durable_save = save_fn

    def evict_barrier(self, step: int, report: object = None) -> ControlAction | None:
        """Checkpoint-before-evict: run a durable save, or veto the eviction.

        Plugged into :class:`~repro.adapt.stragglers.StragglerResponse` as its
        ``evict_barrier``.  Returns the ``ADAPT/checkpoint::before_evict``
        action once a save is durably on disk — the eviction may proceed — or
        ``None`` (no save routine bound, or the save failed), which defers the
        eviction to a later check; the straggler streak keeps growing, so the
        eviction retries as soon as a save succeeds.
        """
        if self._durable_save is None:
            return None
        start = self._clock()
        try:
            seconds = float(self._durable_save(step))
        except Exception as exc:  # noqa: BLE001 - a failed save must veto, not crash
            self.barrier_failures += 1
            del exc
            return None
        if seconds <= 0.0:
            seconds = max(self._clock() - start, 0.0)
        self.barrier_saves += 1
        self.observe_checkpoint(seconds)
        return ControlAction(
            step=step,
            controller="checkpoint",
            trigger=self.ckpt_timer,
            action="before_evict",
            detail={"seconds": round(seconds, 6), "saves": self.barrier_saves},
        )

    # -- steering ---------------------------------------------------------------
    def _apply_steering(self) -> None:
        registry = self._registry
        if registry is None:
            return
        policy = self.inner.policy
        fraction = registry.get(self._fraction_param)
        interval = registry.get(self._interval_param)
        if (fraction, interval) != (policy.max_fraction, policy.max_interval_seconds):
            self.inner.policy = dataclasses.replace(
                policy, max_fraction=fraction, max_interval_seconds=interval
            )
            self.inner.policy.validate()

    # -- Controller protocol ------------------------------------------------------
    def control(
        self, step: int, measurements: Mapping[str, Measurement]
    ) -> list[ControlAction]:
        self._apply_steering()
        now = self._clock()
        # fraction is measured against *loop* wall time (from start_run), not
        # the STARTUP compile — matches the paper's "time spent on the problem"
        total = now - self.inner.started_at
        ckpt = measurements.get(self.ckpt_timer, Measurement(0.0, 0)).seconds
        decision = self.inner.decide(
            iteration=step, now=now, total_seconds=total, checkpoint_seconds=ckpt
        )
        self._pending = decision
        if not decision.checkpoint:
            return []
        return [
            ControlAction(
                step=step,
                controller=self.name,
                trigger=self.ckpt_timer,
                action="checkpoint",
                detail={
                    "reason": decision.reason,
                    "fraction": round(decision.fraction, 6),
                    "predicted_s": round(decision.predicted_seconds, 6),
                },
            )
        ]

    def summary(self) -> dict:
        out = dict(self.inner.summary())
        if self.barrier_saves or self.barrier_failures:
            out["barrier"] = {
                "saves": self.barrier_saves,
                "failures": self.barrier_failures,
            }
        return out
