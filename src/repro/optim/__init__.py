from .adamw import AdamWConfig, adamw_update, init_opt_state, opt_state_axes
from .schedules import constant_lr, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_update",
    "init_opt_state",
    "opt_state_axes",
    "constant_lr",
    "warmup_cosine",
]
