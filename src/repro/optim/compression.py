"""Gradient compression for cross-pod reduction (distributed-optimization trick).

Two pieces:

* **Error-feedback int8 quantization** (`ef_quantize` / `dequantize`): per-leaf
  symmetric int8 with an f32 scale; the quantization residual is carried in an
  error-feedback buffer added back before the next quantization, which keeps
  SGD/Adam convergence (Karimireddy et al. 2019 semantics).

* **Compressed cross-pod all-reduce** (`cross_pod_mean_compressed`): meant to
  run *inside* ``shard_map`` over the ``pod`` axis — all-gather the int8
  payload + f32 scales across pods and reduce locally.  For 2 pods this moves
  ~1 byte/param over the pod links instead of ~4 (bf16 ring all-reduce moves
  2·2 bytes/param), a ~4× collective-bytes cut on the slowest (inter-pod)
  links.  The dry-run variant records the HLO collective-bytes delta in
  EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = ["ef_quantize", "dequantize", "ef_init", "cross_pod_mean_compressed"]


def _q_leaf(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def ef_quantize(tree, ef_buffer):
    """Quantize (tree + ef) to int8; returns (q_tree, scale_tree, new_ef)."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, tree, ef_buffer)
    q_and_s = jax.tree.map(_q_leaf, corrected)
    q = jax.tree.map(lambda t: t[0], q_and_s, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], q_and_s, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(
        lambda c, qq, ss: c - _dq_leaf(qq, ss), corrected, q, s
    )
    return q, s, new_ef


def dequantize(q_tree, scale_tree):
    return jax.tree.map(_dq_leaf, q_tree, scale_tree)


def cross_pod_mean_compressed(tree, ef_buffer, axis_name: str = "pod"):
    """EF-int8 mean over `axis_name` (call inside shard_map over the pod axis).

    Returns (mean_tree_f32, new_ef_buffer).
    """
    n = jax.lax.psum(1, axis_name)
    q, s, new_ef = ef_quantize(tree, ef_buffer)

    def reduce_leaf(qq, ss):
        qg = jax.lax.all_gather(qq, axis_name)          # (pods, ...) int8
        sg = jax.lax.all_gather(ss, axis_name)          # (pods,) f32
        dq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * qq.ndim)
        return jnp.sum(dq, axis=0) / n

    mean = jax.tree.map(reduce_leaf, q, s)
    return mean, new_ef
