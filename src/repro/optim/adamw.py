"""AdamW with mixed precision: bf16 params, f32 master + moments, global-norm
clipping.  Written against plain pytrees (no optax dependency in this offline
container).  Optimizer state inherits the parameters' logical sharding axes —
combined with the ``tp+fsdp`` preset this gives ZeRO-3-style sharded optimizer
state on the data axis.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.sharding import Axes

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "opt_state_axes"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    #: keep an f32 master copy of bf16 params (standard mixed precision)
    master_weights: bool = True


def init_opt_state(cfg: AdamWConfig, params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_axes(cfg: AdamWConfig, axes):
    """Logical axes for the optimizer state, mirroring the parameter axes."""
    state = {
        "step": Axes(()),
        "m": axes,
        "v": axes,
    }
    if cfg.master_weights:
        state["master"] = axes
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig, params, grads, opt_state, lr: jax.Array
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """One AdamW step. Returns (params, opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    masters = opt_state.get("master", params)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        mw = master.astype(jnp.float32)
        new_master = mw - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mw)
        return m, v, new_master

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], masters)
    _is_upd = lambda t: (  # noqa: E731 - (m, v, master) result triple
        isinstance(t, tuple) and len(t) == 3 and not isinstance(t[0], (dict, tuple, list))
    )
    m_new = jax.tree.map(lambda t: t[0], out, is_leaf=_is_upd)
    v_new = jax.tree.map(lambda t: t[1], out, is_leaf=_is_upd)
    master_new = jax.tree.map(lambda t: t[2], out, is_leaf=_is_upd)
    params_new = jax.tree.map(
        lambda mw, p: mw.astype(p.dtype), master_new, params
    )
    new_state = {"step": step, "m": m_new, "v": v_new}
    if cfg.master_weights:
        new_state["master"] = master_new
    stats = {"grad_norm": gnorm, "clip_scale": scale}
    return params_new, new_state, stats
