"""Test-support shims: hypothesis re-exports with a skip-based fallback.

The property-based tests use `hypothesis`, which is a dev-only dependency (see
``pyproject.toml``'s ``dev`` extra).  Importing ``given``/``settings``/
``strategies`` from here instead of from ``hypothesis`` keeps the suite
collectable in minimal environments: when hypothesis is absent, the property
tests are decorated with ``pytest.mark.skip`` and every example-based test in
the same module still runs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAS_HYPOTHESIS = False

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: any attribute is a factory
        returning an inert placeholder, so ``st.floats(0, 1)`` etc. evaluate at
        module import without the real library."""

        def __getattr__(self, name):
            def factory(*args, **kwargs):
                return None

            return factory

    strategies = _StrategyStub()

    def settings(*args, **kwargs):
        """No-op decorator (accepts and ignores hypothesis settings)."""

        def decorate(fn):
            return fn

        return decorate

    def given(*args, **kwargs):
        """Mark the property test as skipped instead of generating examples."""
        import pytest

        def decorate(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return decorate

__all__ = ["given", "settings", "strategies", "HAS_HYPOTHESIS"]
