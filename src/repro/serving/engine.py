"""Continuous-batching serving engine — the supported ``repro.serving`` API.

:class:`ServeSession` keeps ONE persistent decode batch alive: each of its
``n_slots`` cache rows independently carries a request at its own sequence
position, newly admitted requests are prefilled *exactly* (alone, no
cross-request padding) and spliced into free rows while every other row keeps
decoding, and finished rows free their cache blocks for the next admission —
no request ever waits for a batch to drain.  The measured phases are
hierarchical ``repro.timing`` scopes (``serve`` enclosing ``serve/admit``,
``serve/prefill``, ``serve/decode``) and the bookkeeping events are lock-free
counters (``serve/queued|admitted|shed|tokens``), which is what puts serving
on the paper's measure→decide→act loop: a
:class:`~repro.adapt.serving.ServingControl` registered on the session's
:class:`~repro.adapt.controller.ControlLoop` reads those channels and steers
admission width (the steerable ``serving.max_active`` parameter), sheds load
against the :class:`~repro.serving.slo.ServiceLevel`, and records every
decision as an ``ADAPT/serving::*`` row — serving adaptation shares the one
control plane with training (PR-3 follow-up closed; no private steering rule
remains on this path).

Admission is capacity-checked three ways before a request leaves the queue: a
free slot, the ``serving.max_active`` width, and a
:class:`~repro.serving.kvcache.KVCacheManager` block reservation sized to the
request's worst case — so decode can never run out of cache mid-stream.

Correctness invariant (pinned by ``tests/test_serve_consistency.py``): greedy
outputs are token-identical to running each request alone through
``prefill``/``decode_step``, across mid-stream admissions — per-request
prefill is exact, and the decode cache's per-row ``pos`` lets rows at
different positions share one lock-step decode.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.params import ParamRegistry, param_registry
from ..core.timers import TimerDB, timer_db
from ..models import model as M
from ..models.config import ArchConfig
from .batching import Slot, make_cache_splicer, slot_stats
from .kvcache import KVCacheManager
from .slo import ServiceLevel

__all__ = ["Request", "RequestHandle", "RequestResult", "ServeSession"]


@dataclass
class Request:
    """One generation request: prompt tokens in, up to ``max_new_tokens`` out.

    Pure work description — :class:`ServeSession` reports progress and
    completion through :class:`RequestResult`, never by mutating the request.
    """

    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_token: int | None = None


@dataclass
class RequestResult:
    """Per-request view of a finished (or shed) request.

    ``status`` is ``"completed"`` or ``"shed"``; timestamps are
    ``time.monotonic`` values (``admitted_at``/``first_token_at`` are ``None``
    for shed requests, which never reached a slot).  ``truncated`` counts
    prompt tokens dropped at ``submit`` to fit the cache.
    """

    rid: int
    tokens: list[int]
    status: str
    submitted_at: float
    finished_at: float
    admitted_at: float | None = None
    first_token_at: float | None = None
    prompt_len: int = 0
    truncated: int = 0

    @property
    def latency_s(self) -> float:
        """Submit-to-finish wall time."""
        return self.finished_at - self.submitted_at

    @property
    def queue_s(self) -> float | None:
        """Time spent waiting for admission (``None`` if never admitted)."""
        return None if self.admitted_at is None else self.admitted_at - self.submitted_at

    @property
    def ttft_s(self) -> float | None:
        """Submit-to-first-token wall time (``None`` if shed before one)."""
        return None if self.first_token_at is None else self.first_token_at - self.submitted_at

    def stats(self) -> dict[str, object]:
        """The per-request stats row (JSON-ready)."""
        return {
            "rid": self.rid,
            "status": self.status,
            "prompt_len": self.prompt_len,
            "n_tokens": len(self.tokens),
            "truncated": self.truncated,
            "latency_s": self.latency_s,
            "queue_s": self.queue_s,
            "ttft_s": self.ttft_s,
        }


class RequestHandle:
    """Future-like handle returned by :meth:`ServeSession.submit`.

    ``done`` is non-blocking; :meth:`result` cooperatively drives the engine
    (``step()`` in a loop) until this request finishes or is shed — the
    single-threaded analogue of awaiting a server response.
    """

    __slots__ = (
        "request", "_engine", "_result", "_submitted_at", "_admitted_at",
        "_first_token_at", "_tokens", "_truncated", "_slot",
    )

    def __init__(self, request: Request, engine: ServeSession) -> None:
        self.request = request
        self._engine = engine
        self._result: RequestResult | None = None
        self._submitted_at = 0.0
        self._admitted_at: float | None = None
        self._first_token_at: float | None = None
        self._tokens: list[int] = []
        self._truncated = 0
        self._slot = None

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> RequestResult:
        while self._result is None:
            self._engine.step()
        return self._result


def validate_request(req: Request, max_seq: int, n_prefix: int = 0) -> int:
    """Admission validation shared by both engines: reject impossible
    requests, left-truncate (keep the newest tokens of) prompts that would
    overrun the cache.  Returns the number of prompt tokens dropped.

    A request needs ``prompt + max_new_tokens`` cache positions (plus the
    vision-patch prefix for vlm); writing past ``max_seq`` is a silent
    out-of-bounds scatter under jit — wrong outputs, not an error — so the
    bound is enforced here, at submit time.
    """
    if req.max_new_tokens < 1:
        raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
    if not req.prompt:
        raise ValueError(f"request {req.rid}: empty prompt")
    budget = max_seq - req.max_new_tokens - n_prefix
    if budget < 1:
        raise ValueError(
            f"request {req.rid}: max_new_tokens={req.max_new_tokens} leaves no "
            f"prompt room within max_seq={max_seq} (prefix {n_prefix})"
        )
    drop = len(req.prompt) - budget
    if drop > 0:
        req.prompt = list(req.prompt[drop:])
    return max(drop, 0)


def _percentile(values: list[float], q: float) -> float:
    """``np.percentile`` with the degenerate cases pinned: empty -> 0.0,
    single sample -> that sample (no interpolation over a length-1 axis)."""
    if not values:
        return 0.0
    if len(values) == 1:
        return float(values[0])
    return float(np.percentile(values, q))


class ServeSession:
    """The continuous-batching engine over one model + one timing session.

    Parameters
    ----------
    cfg / params:
        Model family configuration and weights (any family
        :mod:`repro.models.model` serves: attention, windowed/hybrid,
        recurrent, vlm, encdec).
    session:
        A :class:`repro.timing.TimingSession` — the primary wiring.  Supplies
        the timer database *and* the control loop the serving controller
        registers on, so serving and training adaptation share one loop.
    n_slots:
        Rows of the persistent decode batch (compiled shape; admission width
        is steered *within* it via ``serving.max_active``).
    max_seq:
        Cache positions per slot (prompt + generated tokens + prefix).
    block_size:
        KV-cache accounting granularity (see
        :class:`~repro.serving.kvcache.KVCacheManager`).
    slo:
        The :class:`~repro.serving.slo.ServiceLevel` the controller enforces;
        ``None`` serves best-effort (no steering targets, no shedding).
    db / registry:
        Escape hatches: explicit timer database (defaults to ``session.db``,
        then the process default) and steerable-parameter registry.
    control:
        When true (default), build and register the
        :class:`~repro.adapt.serving.ServingControl`; the engine polls its
        control loop once per :meth:`step`.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        session=None,
        n_slots: int = 8,
        max_seq: int = 256,
        block_size: int = 16,
        slo: ServiceLevel | None = None,
        db: TimerDB | None = None,
        registry: ParamRegistry | None = None,
        control: bool = True,
    ) -> None:
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.slo = slo if slo is not None else ServiceLevel()
        if session is not None and db is None:
            db = session.db
        self._db = db if db is not None else timer_db()
        self._registry = registry if registry is not None else param_registry()
        self._registry.declare(
            "serving.max_active", n_slots, steerable=True,
            doc="admission width of the persistent decode batch "
                "(steered by ADAPT/serving from decode latency)",
            validator=lambda v: isinstance(v, int) and v >= 1,
        )
        self._n_prefix = cfg.n_vision_patches if cfg.family == "vlm" else 0

        # phase scopes pre-resolved once; real paths, so `serve` parents them
        self._scope_serve = self._db.scope_handle("serve")
        self._scope_admit = self._db.scope_handle("serve/admit")
        self._scope_prefill = self._db.scope_handle("serve/prefill")
        self._scope_decode = self._db.scope_handle("serve/decode")
        from ..timing.scopes import counter

        self._c_queued = counter("serve/queued", db=self._db)
        self._c_admitted = counter("serve/admitted", db=self._db)
        self._c_shed = counter("serve/shed", db=self._db)
        self._c_tokens = counter("serve/tokens", db=self._db)

        self.kv = KVCacheManager(
            cfg, n_slots=n_slots, max_seq=max_seq, block_size=block_size, db=self._db
        )
        self._slots = [Slot(i) for i in range(n_slots)]
        self._queue: deque[RequestHandle] = deque()
        self.completed: list[RequestResult] = []
        self.shed_results: list[RequestResult] = []
        self._steps = 0
        self._tokens_emitted = 0

        self._cache = None  # allocated lazily on first admission
        self._next_tok = np.zeros(n_slots, np.int32)
        self._jit_prefill = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
        self._jit_decode = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
        self._splice = make_cache_splicer(cfg, n_slots, max_seq)

        self._control = None
        self._loop = None
        if control:
            if session is not None:
                self._loop = session.control_loop
            else:
                from ..adapt.controller import ControlLoop

                self._loop = ControlLoop(self._db)
            from ..adapt.serving import ServingControl

            self._control = ServingControl(self, slo=self.slo, registry=self._registry)
            self._loop.register(self._control)

    # -- introspection ----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def active_slots(self) -> int:
        return sum(1 for s in self._slots if not s.free)

    @property
    def max_active(self) -> int:
        """Effective admission width: the steerable parameter, capped at the
        compiled slot count."""
        return min(int(self._registry.get("serving.max_active")), self.n_slots)

    @property
    def control_loop(self):
        """The adapt loop serving decisions land on (``None`` with
        ``control=False``)."""
        return self._loop

    # -- submission -------------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Validate, enqueue, and return the request's future-like handle.

        Prompts that would overrun the cache are left-truncated (newest
        tokens kept) — the drop count lands on ``RequestResult.truncated``;
        impossible requests (empty prompt, ``max_new_tokens`` that cannot fit
        at all) raise ``ValueError`` here rather than corrupting the cache.
        """
        truncated = validate_request(request, self.max_seq, self._n_prefix)
        handle = RequestHandle(request, self)
        handle._submitted_at = time.monotonic()
        handle._truncated = truncated
        self._queue.append(handle)
        self._c_queued(1)
        return handle

    # -- actuators (driven by ADAPT/serving) -------------------------------------
    def shed(self, n: int) -> list[RequestResult]:
        """Drop ``n`` queued requests per the SLO's ``shed_from`` policy;
        their handles resolve immediately with ``status="shed"``."""
        dropped: list[RequestResult] = []
        now = time.monotonic()
        for _ in range(min(n, len(self._queue))):
            handle = (
                self._queue.popleft() if self.slo.shed_from == "oldest"
                else self._queue.pop()
            )
            result = RequestResult(
                rid=handle.rid, tokens=[], status="shed",
                submitted_at=handle._submitted_at, finished_at=now,
                prompt_len=len(handle.request.prompt),
                truncated=handle._truncated,
            )
            handle._result = result
            self.shed_results.append(result)
            dropped.append(result)
            self._c_shed(1)
        return dropped

    def completion_rate(self) -> float:
        """Recent requests-per-second, measured over busy (``serve``-scoped)
        seconds — the rate the SLO queue-delay estimate divides by."""
        busy = self._scope_serve.timer.seconds()
        if busy <= 0.0:
            return 0.0
        return len(self.completed) / busy

    # -- the engine iteration ----------------------------------------------------
    def step(self) -> list[RequestResult]:
        """One engine iteration: admit into free slots, one lock-step decode,
        harvest finished requests, poll the control loop.  Returns the
        requests that finished this step."""
        finished: list[RequestResult] = []
        if self._queue or self.active_slots:
            self._steps += 1
            with self._scope_serve:
                self._admit(finished)
                if self.active_slots:
                    self._decode_once(finished)
            if self._loop is not None:
                self._loop.poll(self._steps)
        return finished

    def run_until_idle(self, max_steps: int | None = None) -> list[RequestResult]:
        """Drive :meth:`step` until queue and slots are empty; returns every
        request completed during the drain (shed requests excluded)."""
        drained: list[RequestResult] = []
        while self._queue or self.active_slots:
            drained.extend(self.step())
            if max_steps is not None:
                max_steps -= 1
                if max_steps <= 0:
                    break
        return drained

    # -- internals ---------------------------------------------------------------
    def _admit(self, finished: list[RequestResult]) -> None:
        while True:
            with self._scope_admit:
                handle = self._pick_admission()
            if handle is None:
                return
            self._prefill_into_slot(handle, finished)

    def _pick_admission(self) -> RequestHandle | None:
        if not self._queue or self.active_slots >= self.max_active:
            return None
        slot = next((s for s in self._slots if s.free), None)
        if slot is None:
            return None
        head = self._queue[0]
        req = head.request
        total = self._n_prefix + len(req.prompt) + req.max_new_tokens
        if not self.kv.can_admit(total):
            return None
        self._queue.popleft()
        blocks = self.kv.allocate(req.rid, total)
        slot.bind(req, head, blocks)
        head._slot = slot
        self._c_admitted(1)
        return head

    def _prefill_batch(self, req: Request) -> dict:
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_vision_patches, self.cfg.d_model), jnp.bfloat16
            )
        if self.cfg.family == "encdec":
            batch["src_frames"] = jnp.zeros(
                (1, len(req.prompt), self.cfg.d_model), jnp.bfloat16
            )
        return batch

    def _prefill_into_slot(self, handle: RequestHandle, finished: list[RequestResult]) -> None:
        slot: Slot = handle._slot
        req = handle.request
        now = time.monotonic()
        handle._admitted_at = now
        with self._scope_prefill:
            fresh = M.init_cache(self.cfg, 1, self.max_seq)
            fresh, logits = self._jit_prefill(self.params, self._prefill_batch(req), fresh)
            logits = jax.block_until_ready(logits)
        tok = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
        handle._tokens = [tok]
        handle._first_token_at = time.monotonic()
        slot.generated = 1
        self._tokens_emitted += 1
        self._c_tokens(1)
        if req.max_new_tokens == 1 or (req.eos_token is not None and tok == req.eos_token):
            self._finish(slot, finished)
            return
        if self._cache is None:
            self._cache = M.init_cache(self.cfg, self.n_slots, self.max_seq)
        self._cache = self._splice(self._cache, fresh, jnp.int32(slot.index))
        self._next_tok[slot.index] = tok

    def _decode_once(self, finished: list[RequestResult]) -> None:
        with self._scope_decode:
            self._cache, logits = self._jit_decode(
                self.params, self._cache, jnp.asarray(self._next_tok[:, None])
            )
            logits = jax.block_until_ready(logits)
        toks = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1))
        emitted = 0
        for slot in self._slots:
            if slot.free:
                continue
            tok = int(toks[slot.index])
            slot.handle._tokens.append(tok)
            slot.generated += 1
            self._next_tok[slot.index] = tok
            emitted += 1
            req = slot.request
            if slot.generated >= req.max_new_tokens or (
                req.eos_token is not None and tok == req.eos_token
            ):
                self._finish(slot, finished)
        self._tokens_emitted += emitted
        if emitted:
            self._c_tokens(emitted)

    def _finish(self, slot: Slot, finished: list[RequestResult]) -> None:
        handle: RequestHandle = slot.handle
        req = slot.request
        result = RequestResult(
            rid=req.rid,
            tokens=list(handle._tokens),
            status="completed",
            submitted_at=handle._submitted_at,
            finished_at=time.monotonic(),
            admitted_at=handle._admitted_at,
            first_token_at=handle._first_token_at,
            prompt_len=len(req.prompt),
            truncated=handle._truncated,
        )
        handle._result = result
        self.kv.free(req.rid)
        slot.release()
        self.completed.append(result)
        finished.append(result)

    # -- read side ---------------------------------------------------------------
    def stats(self) -> dict[str, float]:
        """Engine-level view: throughput, latency distribution, occupancy,
        shedding, and the KV pool (per-request rows live on
        :meth:`request_stats` / :meth:`RequestResult.stats`)."""
        lat = [r.latency_s for r in self.completed]
        ttft = [r.ttft_s for r in self.completed if r.ttft_s is not None]
        busy = self._scope_serve.timer.seconds()
        occupancy = slot_stats(self._slots)
        return {
            "completed": float(len(self.completed)),
            "shed": float(len(self.shed_results)),
            "queue_depth": float(self.queue_depth),
            "active_slots": float(occupancy.active),
            "occupancy": occupancy.occupancy,
            "max_active": float(self.max_active),
            "steps": float(self._steps),
            "tokens": float(self._tokens_emitted),
            "throughput_tokens_per_s": self._tokens_emitted / busy if busy > 0 else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p95_latency_s": _percentile(lat, 95),
            "p95_ttft_s": _percentile(ttft, 95),
            "kv_utilization": self.kv.utilization(),
            "kv_high_water_blocks": float(self.kv.high_water),
        }

    def request_stats(self) -> list[dict[str, object]]:
        """Per-request stats rows, completed then shed, submission order."""
        rows = [r.stats() for r in self.completed]
        rows.extend(r.stats() for r in self.shed_results)
        return rows
