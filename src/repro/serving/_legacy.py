"""Deprecated static-batch engine (the pre-continuous-batching API).

:class:`ServingEngine` is the PR-4-era serving loop: admit up to ``max_batch``
queued requests (left-padded to a common prompt length), one jitted prefill,
lock-step decode until **every** request in the batch finishes, then the next
batch — and a private halve/double rule steering ``serving.max_batch`` off
the control plane.  It is kept byte-for-byte behavioral (modulo the admit-path
crash fixes below) behind a ``DeprecationWarning`` per the ROADMAP
deprecation policy: exact behavior + warning for >= 2 PRs before removal.
New code uses :class:`repro.serving.ServeSession`, whose steering lives on
the adapt control plane (``ADAPT/serving::*`` rows).

Fixes folded in (covered by ``tests/test_serving.py``): ``submit`` now
validates/truncates prompts that would overrun ``max_seq`` (previously a
silent out-of-bounds cache scatter), and ``stats`` guards the percentile of
degenerate completion lists.
"""

from __future__ import annotations

import time
import warnings
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..core.params import ParamRegistry, param_registry
from ..core.timers import TimerDB, timer_db
from ..models import model as M
from ..models.config import ArchConfig
from .engine import Request, _percentile, validate_request

__all__ = ["ServingEngine"]


class ServingEngine:
    """Deprecated: use :class:`repro.serving.ServeSession` (continuous
    batching on the adapt control plane).  See the README migration table."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_batch: int = 8,
        max_seq: int = 256,
        target_decode_ms: float | None = None,
        db: TimerDB | None = None,
        registry: ParamRegistry | None = None,
        session=None,
    ) -> None:
        """``session`` (a :class:`repro.timing.TimingSession`) supplies the
        timer database when given — the session-wired path; ``db`` remains the
        explicit-database escape hatch, and the process default is used when
        neither is passed."""
        warnings.warn(
            "ServingEngine is deprecated; use repro.serving.ServeSession "
            "(continuous batching, steered on the adapt control plane)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.target_decode_ms = target_decode_ms
        if session is not None and db is None:
            db = session.db
        self._db = db if db is not None else timer_db()
        # phase scopes pre-resolved once (repro.timing hot path); names are
        # real paths, so `serve` is the parent of the three phase timers
        self._scope_serve = self._db.scope_handle("serve")
        self._scope_admit = self._db.scope_handle("serve/admit")
        self._scope_prefill = self._db.scope_handle("serve/prefill")
        self._scope_decode = self._db.scope_handle("serve/decode")
        self._registry = registry if registry is not None else param_registry()
        self._registry.declare(
            "serving.max_batch", max_batch, steerable=True,
            doc="admitted batch size (self-steered from decode latency)",
            validator=lambda v: isinstance(v, int) and v >= 1,
        )
        self._hard_max = max_batch
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self._decode_ms_history: list[float] = []

        self._prefill = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
        self._decode = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        n_prefix = self.cfg.n_vision_patches if self.cfg.family == "vlm" else 0
        validate_request(req, self.max_seq, n_prefix)
        req.admitted_at = time.monotonic()
        self.queue.append(req)

    @property
    def max_batch(self) -> int:
        return int(self._registry.get("serving.max_batch"))

    # -- one engine iteration ------------------------------------------------
    def step_batch(self) -> list[Request]:
        """Admit → prefill → decode-to-completion for one batch."""
        if not self.queue:
            return []
        with self._scope_serve:
            return self._step_batch_scoped()

    def _step_batch_scoped(self) -> list[Request]:
        with self._scope_admit:
            batch_reqs: list[Request] = []
            while self.queue and len(batch_reqs) < self.max_batch:
                batch_reqs.append(self.queue.popleft())
            b = len(batch_reqs)
            plen = max(len(r.prompt) for r in batch_reqs)
            tokens = np.zeros((b, plen), np.int32)
            for i, r in enumerate(batch_reqs):
                tokens[i, plen - len(r.prompt):] = r.prompt  # left-pad
        with self._scope_prefill:
            cache = M.init_cache(self.cfg, b, self.max_seq)
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (b, self.cfg.n_vision_patches, self.cfg.d_model), jnp.bfloat16
                )
            if self.cfg.family == "encdec":
                batch["src_frames"] = jnp.zeros((b, plen, self.cfg.d_model), jnp.bfloat16)
            cache, logits = self._prefill(self.params, batch, cache)
            logits = jax.block_until_ready(logits)
        max_new = max(r.max_new_tokens for r in batch_reqs)
        next_tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
        done = np.zeros(b, bool)
        n_decoded = 0
        decode_before = self._scope_decode.seconds()
        with self._scope_decode as decode_timer:
            for step_i in range(max_new):
                for i, r in enumerate(batch_reqs):
                    if not done[i]:
                        tok = int(next_tok[i])
                        r.output.append(tok)
                        if (r.eos_token is not None and tok == r.eos_token) or len(
                            r.output
                        ) >= r.max_new_tokens:
                            done[i] = True
                n_decoded += 1
                if done.all() or step_i == max_new - 1:
                    break
                cache, logits = self._decode(self.params, cache, next_tok[:, None])
                logits = jax.block_until_ready(logits)
                next_tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(
                    jnp.int32
                )
        decode_s = decode_timer.seconds() - decode_before
        per_token_ms = 1e3 * decode_s / max(n_decoded, 1)
        self._decode_ms_history.append(per_token_ms)
        self._steer_batch_size(per_token_ms)
        now = time.monotonic()
        for r in batch_reqs:
            r.finished_at = now
            self.completed.append(r)
        return batch_reqs

    def run(self) -> list[Request]:
        while self.queue:
            self.step_batch()
        return self.completed

    # -- self-steering (the rule ServingControl replaced; kept for exact
    # -- deprecated behavior until removal) ----------------------------------
    def _steer_batch_size(self, per_token_ms: float) -> None:
        if self.target_decode_ms is None:
            return
        current = self.max_batch
        if per_token_ms > self.target_decode_ms and current > 1:
            self._registry.set("serving.max_batch", max(current // 2, 1))
        elif per_token_ms < 0.5 * self.target_decode_ms and current < self._hard_max:
            self._registry.set("serving.max_batch", min(current * 2, self._hard_max))

    def stats(self) -> dict[str, float]:
        lat = [r.finished_at - r.admitted_at for r in self.completed]
        return {
            "completed": float(len(self.completed)),
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p95_latency_s": _percentile(lat, 95),
            "decode_ms_per_token_last": self._decode_ms_history[-1]
            if self._decode_ms_history
            else 0.0,
            "max_batch": float(self.max_batch),
        }
