"""Block-based KV-cache accounting for the continuous-batching engine.

The persistent decode cache is allocated dense (``n_slots`` slots of
``max_seq`` positions — the layout :func:`repro.models.model.init_cache`
produces), but admission reasons about it in fixed-size **blocks**, the unit
production engines page in (vLLM-style): a request reserves
``ceil(tokens / block_size)`` blocks at admission and frees them on
completion, so "is there cache room?" is a pool arithmetic question and the
shed/admit decisions on the control plane see one number — block utilization —
regardless of model family.

The per-request token footprint is family-aware:

* **global attention** (``attn`` / ``xattn`` blocks): K/V grow with the
  sequence, so a request costs ``prompt + max_new`` token positions (capped at
  ``max_seq``);
* **windowed attention only** (``attn_local``): the ring buffer bounds the
  footprint at ``window`` positions however long the request runs;
* **pure recurrent** (``rglru`` / ``rwkv``): state is O(1) per request — one
  block, the "recurrent-state slot".

Block mapping is slot-contiguous (slot ``i``, block ``j`` covers positions
``[j*block_size, (j+1)*block_size)`` of that slot), so reservations never
fragment; what the manager adds over raw slot counting is the *token-level*
admission bound and the utilization counters (``serve/kv_alloc_blocks`` /
``serve/kv_freed_blocks`` via :func:`repro.timing.counter`) that the
:class:`~repro.adapt.serving.ServingControl` and the reports read.
"""

from __future__ import annotations

import math

from ..core.timers import TimerDB
from ..models.config import ArchConfig
from ..models.model import decoder_pattern

__all__ = ["KVCacheManager"]


def _effective_seq(cfg: ArchConfig, max_seq: int) -> int:
    """Token positions one request can occupy in the cache: ``max_seq`` for
    global attention, the window for window-only stacks, 0 (constant state)
    for pure recurrent families."""
    kinds = set(decoder_pattern(cfg))
    if kinds & {"attn", "xattn"}:
        return max_seq
    if "attn_local" in kinds:
        return min(cfg.window or max_seq, max_seq)
    return 0


class KVCacheManager:
    """Alloc/free block accounting over one dense ``n_slots x max_seq`` cache.

    Parameters
    ----------
    cfg:
        Model config; decides the family footprint rule (see module doc).
    n_slots / max_seq:
        Geometry of the persistent decode cache being accounted for.
    block_size:
        Tokens per block (power-of-two sizes round-trip best, but any
        positive size works).
    db:
        Timer database whose counter channels receive the alloc/free totals
        (process default when ``None``).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        n_slots: int,
        max_seq: int,
        block_size: int = 16,
        db: TimerDB | None = None,
    ) -> None:
        if n_slots < 1 or max_seq < 1 or block_size < 1:
            raise ValueError("n_slots, max_seq and block_size must be >= 1")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.block_size = block_size
        self._eff_seq = _effective_seq(cfg, max_seq)
        #: blocks one fully-loaded request can reserve (>= 1 even for the
        #: recurrent families, whose state occupies one block per request)
        self.blocks_per_slot = max(1, math.ceil(self._eff_seq / block_size))
        self.total_blocks = n_slots * self.blocks_per_slot
        self._reserved: dict[int, int] = {}
        self._high_water = 0
        from ..timing.scopes import counter

        self._c_alloc = counter("serve/kv_alloc_blocks", db=db)
        self._c_freed = counter("serve/kv_freed_blocks", db=db)

    # -- sizing -----------------------------------------------------------------
    def blocks_for(self, total_tokens: int) -> int:
        """Blocks a request spanning ``total_tokens`` positions reserves."""
        if total_tokens < 0:
            raise ValueError(f"negative token count {total_tokens}")
        tokens = min(total_tokens, self._eff_seq)
        return max(1, math.ceil(tokens / self.block_size))

    # -- pool state -------------------------------------------------------------
    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    @property
    def free_blocks(self) -> int:
        return self.total_blocks - self.reserved_blocks

    @property
    def high_water(self) -> int:
        """Peak reserved blocks over the manager's lifetime."""
        return self._high_water

    def utilization(self) -> float:
        """Reserved fraction of the pool, 0..1."""
        return self.reserved_blocks / self.total_blocks

    # -- alloc / free -----------------------------------------------------------
    def can_admit(self, total_tokens: int) -> bool:
        return self.blocks_for(total_tokens) <= self.free_blocks

    def allocate(self, rid: int, total_tokens: int) -> int:
        """Reserve blocks for request ``rid``; returns the count reserved.

        Reservation happens once, at admission, for the request's worst case
        (prompt + max new tokens), so decode can never run out of cache
        mid-stream — admission control is where "full" is decided.
        """
        if rid in self._reserved:
            raise ValueError(f"request {rid} already holds blocks")
        need = self.blocks_for(total_tokens)
        if need > self.free_blocks:
            raise ValueError(
                f"request {rid} needs {need} blocks, only {self.free_blocks} free"
            )
        self._reserved[rid] = need
        self._high_water = max(self._high_water, self.reserved_blocks)
        self._c_alloc(need)
        return need

    def free(self, rid: int) -> int:
        """Release request ``rid``'s blocks; returns the count released."""
        freed = self._reserved.pop(rid, 0)
        if freed:
            self._c_freed(freed)
        return freed

    def stats(self) -> dict[str, float]:
        return {
            "total_blocks": float(self.total_blocks),
            "reserved_blocks": float(self.reserved_blocks),
            "free_blocks": float(self.free_blocks),
            "high_water_blocks": float(self._high_water),
            "utilization": self.utilization(),
            "block_size": float(self.block_size),
        }
