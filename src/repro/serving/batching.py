"""Slot state and cache splicing — the in-flight half of continuous batching.

The engine keeps ONE persistent decode cache of ``n_slots`` batch rows and
never re-batches it: a newly admitted request is prefilled alone (exact, no
cross-request padding) into a single-row cache and **spliced** into a free
slot, while every other slot keeps decoding.  Splicing is a pure jitted
scatter over the cache pytree: for each leaf, the batch axis (located by the
leaf's logical axes from :func:`repro.models.model.cache_axes`) is rotated to
the front, row ``slot`` is overwritten with the fresh row, and the axis is
rotated back — XLA fuses the transposes into the scatter, and the compiled
splice is shared by every admission because its shapes never change.

Heterogeneous progress needs no masking machinery: the decode cache carries a
per-row ``pos`` (see :func:`repro.models.model.decode_step` and
``_attn_core_decode``, which rotate, scatter, and mask per element), so slots
prefilled at different times simply decode at different positions in the same
lock-step call.  Free slots decode garbage that is never read; their cache
rows are fully overwritten by the next splice.
"""

from __future__ import annotations

from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig

__all__ = ["Slot", "make_cache_splicer"]


def make_cache_splicer(
    cfg: ArchConfig, n_slots: int, max_seq: int
) -> Callable[[Any, Any, jax.Array], Any]:
    """Build the jitted ``splice(dst_cache, src_cache, slot) -> dst_cache``.

    ``dst_cache`` is the persistent ``n_slots``-row cache, ``src_cache`` a
    freshly prefilled single-row cache of the same ``max_seq``; ``slot`` the
    destination row index.  Works for every family because the batch axis is
    found per leaf from the cache's logical-axes tree, not assumed positional.
    """
    axes = M.cache_axes(cfg, n_slots, max_seq)

    def _splice(dst, src, slot):
        leaves_d, treedef = jax.tree_util.tree_flatten(dst)
        leaves_s = jax.tree_util.tree_leaves(src)
        leaves_a = treedef.flatten_up_to(axes)
        out = []
        for d, s, ax in zip(leaves_d, leaves_s, leaves_a):
            b = ax.index("batch")
            d2 = jnp.moveaxis(d, b, 0)
            s2 = jnp.moveaxis(s, b, 0)
            out.append(jnp.moveaxis(d2.at[slot].set(s2[0]), 0, b))
        return jax.tree_util.tree_unflatten(treedef, out)

    return jax.jit(_splice)


@dataclass
class Slot:
    """One row of the persistent decode batch.

    A slot is *free* when ``request is None``; admission binds a request (and
    its result-in-progress) to the slot, completion unbinds it.  ``generated``
    counts emitted tokens (the prefill token included), so
    ``generated == request.max_new_tokens`` is the length stop.
    """

    index: int
    request: Any | None = None
    handle: Any | None = None
    generated: int = 0
    blocks: int = 0

    @property
    def free(self) -> bool:
        return self.request is None

    def bind(self, request, handle, blocks: int) -> None:
        self.request = request
        self.handle = handle
        self.generated = 0
        self.blocks = blocks

    def release(self) -> None:
        self.request = None
        self.handle = None
        self.generated = 0
        self.blocks = 0


@dataclass
class SlotStats:
    """Aggregate view over the slot array (engine-level ``stats()`` rows)."""

    n_slots: int
    active: int
    free: int
    occupancy: float = field(init=False)

    def __post_init__(self) -> None:
        self.occupancy = self.active / self.n_slots if self.n_slots else 0.0


def slot_stats(slots: list[Slot]) -> SlotStats:
    active = sum(1 for s in slots if not s.free)
    return SlotStats(n_slots=len(slots), active=active, free=len(slots) - active)
