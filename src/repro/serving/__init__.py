"""``repro.serving`` — the continuous-batching inference engine.

The supported surface (frozen by ``tests/test_api_surface.py``):

* :class:`ServeSession` — persistent decode batch with per-slot state;
  ``submit()`` returns a :class:`RequestHandle` (``.done`` / ``.result()``),
  ``step()``/``run_until_idle()`` drive the engine, ``stats()`` is the
  engine-level view and ``request_stats()`` / :class:`RequestResult` the
  per-request one.  Construct with ``session=`` (a
  :class:`repro.timing.TimingSession`) so measurements and the serving
  controller land on that session's database and control loop.
* :class:`Request` — the work item (prompt, ``max_new_tokens``, eos).
* :class:`ServiceLevel` — latency/queueing objectives the
  ``ADAPT/serving`` controller enforces.
* :class:`KVCacheManager` — block-based cache accounting (admission bound +
  utilization counters).

The deprecated static-batch ``ServingEngine`` shim was removed after its
two-PR deprecation window (ROADMAP deprecation policy); the README "Serving"
migration table maps its surface onto :class:`ServeSession`.
"""

from .engine import Request, RequestHandle, RequestResult, ServeSession
from .kvcache import KVCacheManager
from .slo import ServiceLevel

__all__ = [
    "KVCacheManager",
    "Request",
    "RequestHandle",
    "RequestResult",
    "ServeSession",
    "ServiceLevel",
]
