"""Latency service-level objectives and the admission/shedding arithmetic.

:class:`ServiceLevel` is the declarative half — the operator states what
"acceptable" means (decode-step latency target, maximum tolerable queueing
delay); the pure functions below turn measurements into decisions.  The
*decision-taking* lives on the adapt control plane
(:class:`repro.adapt.serving.ServingControl` reads the ``serve/decode`` timer
channel and the queue, calls these helpers, and records every resulting
action as an ``ADAPT/serving::*`` row) — this module deliberately holds no
state and touches no engine, so the policy is unit-testable arithmetic.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["ServiceLevel", "estimated_queue_delay", "shed_count"]


@dataclass(frozen=True)
class ServiceLevel:
    """What the operator promised users, as numbers.

    Parameters
    ----------
    target_decode_ms:
        Latency target for one decode step of the persistent batch (the
        cadence at which every in-flight request receives its next token).
        ``None`` disables batch-size steering.
    max_queue_delay_s:
        Largest acceptable *estimated* wait in the admission queue; pending
        requests beyond it are shed rather than served late.  ``None``
        disables shedding.
    grow_headroom:
        Fraction of ``target_decode_ms`` under which the batch is considered
        comfortable and admission may widen (grow is attempted below
        ``grow_headroom * target``, shrink above ``target``).
    shed_from:
        Which end of the queue sheds first: ``"newest"`` preserves
        first-come-first-served fairness for the requests already waiting;
        ``"oldest"`` bounds worst-case staleness instead.
    """

    target_decode_ms: float | None = None
    max_queue_delay_s: float | None = None
    grow_headroom: float = 0.5
    shed_from: str = "newest"

    def __post_init__(self) -> None:
        if self.target_decode_ms is not None and self.target_decode_ms <= 0:
            raise ValueError("target_decode_ms must be positive")
        if self.max_queue_delay_s is not None and self.max_queue_delay_s <= 0:
            raise ValueError("max_queue_delay_s must be positive")
        if not 0.0 < self.grow_headroom <= 1.0:
            raise ValueError("grow_headroom must be in (0, 1]")
        if self.shed_from not in ("oldest", "newest"):
            raise ValueError("shed_from must be 'oldest' or 'newest'")


def estimated_queue_delay(queue_depth: int, completion_rate: float) -> float | None:
    """Expected wait of the *last* queued request, in seconds.

    ``completion_rate`` is the engine's recent requests-per-second; with an
    open admission loop the queue drains at that rate, so the tail request
    waits ``depth / rate``.  Returns ``None`` (no estimate, never shed on it)
    until the engine has completed enough work to measure a rate.
    """
    if queue_depth <= 0:
        return 0.0
    if completion_rate <= 0.0:
        return None
    return queue_depth / completion_rate


def shed_count(queue_depth: int, completion_rate: float, slo: ServiceLevel) -> int:
    """How many queued requests to shed so the estimated tail wait meets the
    SLO.  Zero when shedding is disabled, the estimate is unavailable, or the
    queue already meets the objective."""
    if slo.max_queue_delay_s is None or queue_depth <= 0:
        return 0
    delay = estimated_queue_delay(queue_depth, completion_rate)
    if delay is None or delay <= slo.max_queue_delay_s:
        return 0
    keep = int(slo.max_queue_delay_s * completion_rate)
    return max(queue_depth - keep, 0)
