"""Observability soak harness: wall-clock train/serve drives with fault
injection, periodic ``/metrics`` scrapes, and long-run boundedness invariants
(see :mod:`repro.soak.run` for the CLI: ``python -m repro.soak``)."""

from .invariants import SnapshotRecord, check_snapshots
from .run import SoakConfig, SoakResult, main, run_soak

__all__ = [
    "SnapshotRecord",
    "SoakConfig",
    "SoakResult",
    "check_snapshots",
    "main",
    "run_soak",
]
