"""Long-run observability soak: drive train or serve for a wall-clock budget,
scrape ``/metrics`` every interval, and assert the boundedness invariants.

Where :mod:`repro.faults.soak` proves the system *recovers* from injected
faults over a fixed step count, this harness proves the whole
measure → decide → act → **export** stack stays healthy over wall-clock time:

* **train mode** — a :class:`~repro.adapt.fleet.SimulatedFleet` under a
  :class:`~repro.adapt.ControlLoop` with seeded PR-7
  :class:`~repro.faults.plan.FaultPlan` slow/hang/restore injections for the
  first ~60% of the budget, then a fault-free steady tail;
* **serve mode** — a :class:`~repro.serving.ServeSession` (smoke config) under
  seeded open-loop traffic bursts, its ``ADAPT/serving`` controller steering
  batch width and shedding.

Every ``--interval-s`` the run scrapes the live monitor ``/metrics`` endpoint
(or renders in-process with ``--no-http``), parses it with the strict
exposition parser, and records the control loop's decision log as the delta
baseline.  After the budget, :func:`repro.soak.invariants.check_snapshots`
asserts: clean parses, strictly increasing scrape clock, no ``*_total``
decrease, every ADAPT action externally visible, and flat timer/bucket/channel
cardinality over the steady tail.  Exit code is non-zero on any failure:

    PYTHONPATH=src python -m repro.soak --mode both --budget-s 60 \\
        --interval-s 5 --seed 1 --out-dir soak_snapshots
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
import urllib.request

from .invariants import SnapshotRecord, check_snapshots

__all__ = ["SoakConfig", "SoakResult", "main", "run_soak"]

#: steps per fault-plan round (train mode): each round draws a fresh seeded
#: plan, so fault pressure tracks however many steps the wall clock admits
_FAULT_ROUND = 256


@dataclasses.dataclass
class SoakConfig:
    mode: str = "train"            # "train" | "serve"
    budget_s: float = 60.0         # wall-clock budget for the drive loop
    interval_s: float = 5.0        # snapshot cadence (auto-shrunk if needed)
    seed: int = 0
    n_hosts: int = 4
    n_micro: int = 8
    fault_rate: float = 0.03       # per-step fault probability (train)
    fault_fraction: float = 0.6    # faults land only in this budget prefix
    scrape_http: bool = True       # scrape the live monitor; else render
    out_dir: str | None = None     # write each snapshot as a .prom file
    min_snapshots: int = 4
    tail_fraction: float = 0.25


@dataclasses.dataclass
class SoakResult:
    mode: str
    steps: int
    snapshots: list[SnapshotRecord]
    failures: list[str]
    summary: dict

    @property
    def ok(self) -> bool:
        return not self.failures


class _Scraper:
    """Snapshot taker: scrape (or render), strictly parse, optionally persist."""

    def __init__(self, cfg: SoakConfig, exporter, loop, server) -> None:
        from ..monitor import TEXT_CONTENT_TYPE

        self._cfg = cfg
        self._exporter = exporter
        self._loop = loop
        self._server = server
        self._ctype = TEXT_CONTENT_TYPE
        self.records: list[SnapshotRecord] = []
        if cfg.out_dir:
            os.makedirs(cfg.out_dir, exist_ok=True)

    def snap(self, step: int) -> SnapshotRecord:
        from ..monitor import ExpositionError, parse_exposition

        cfg = self._cfg
        # the delta baseline MUST be taken before the scrape: the invariant is
        # "every action already in the log is visible on the wire"
        actions = dict(self._loop.summary()["action_counts"])
        record = SnapshotRecord(
            index=len(self.records), step=step, actions=actions,
            source="http" if self._server is not None else "render",
        )
        try:
            if self._server is not None:
                url = f"http://127.0.0.1:{self._server.port}/metrics"
                with urllib.request.urlopen(url, timeout=30) as resp:
                    ctype = resp.headers.get("Content-Type", "")
                    text = resp.read().decode("utf-8")
                if ctype != self._ctype:
                    record.parse_error = f"wrong content type {ctype!r}"
            else:
                text = self._exporter.render()
        except OSError as exc:
            record.parse_error = f"scrape failed: {exc}"
            text = ""
        if record.parse_error is None:
            try:
                record.exposition = parse_exposition(text)
            except ExpositionError as exc:
                record.parse_error = str(exc)
        if cfg.out_dir and text:
            record.path = os.path.join(
                cfg.out_dir, f"{cfg.mode}_{record.index:03d}.prom"
            )
            with open(record.path, "w", encoding="utf-8") as f:
                f.write(text)
        self.records.append(record)
        return record


def _effective_interval(cfg: SoakConfig) -> float:
    """Shrink the cadence so even a tiny budget yields enough snapshots for
    the tail math (min_snapshots, >= 2 of them in the tail)."""
    return max(min(cfg.interval_s, cfg.budget_s / (cfg.min_snapshots + 1)), 0.01)


def _soak_train(cfg: SoakConfig) -> SoakResult:
    from ..adapt import ControlLoop
    from ..adapt.fleet import SimulatedFleet
    from ..core.timers import TimerDB
    from ..faults.inject import apply_fleet_event
    from ..faults.plan import FLEET_FAULTS, FaultPlan
    from ..monitor import MetricsExporter, MonitorServer

    db = TimerDB()
    fleet = SimulatedFleet(
        cfg.n_hosts, cfg.n_micro, window=4, threshold=1.5, evict_after=6, db=db
    )
    loop = ControlLoop(db=db)
    loop.register(fleet.controller)
    exporter = MetricsExporter(db, control_loop=loop, detector=fleet.detector)
    server = None
    if cfg.scrape_http:
        server = MonitorServer(0, db, exporter=exporter)
        server.start()
    scraper = _Scraper(cfg, exporter, loop, server)

    interval = _effective_interval(cfg)
    t0 = time.monotonic()
    deadline = t0 + cfg.budget_s
    fault_deadline = t0 + cfg.budget_s * cfg.fault_fraction
    next_snap = t0 + interval
    step = 0
    n_faults = 0
    plan = None
    try:
        while time.monotonic() < deadline:
            round_idx, offset = divmod(step, _FAULT_ROUND)
            if offset == 0:
                plan = (
                    FaultPlan.random(
                        cfg.seed + 7919 * round_idx, _FAULT_ROUND,
                        kinds=FLEET_FAULTS, rate=cfg.fault_rate,
                        hosts=range(cfg.n_hosts),
                    )
                    if time.monotonic() < fault_deadline
                    else None
                )
            if plan is not None:
                for event in plan.at(offset):
                    if event.target in fleet.costs:
                        n_faults += 1
                        apply_fleet_event(event, fleet)
            fleet.run_step(step)
            loop.poll(step)
            step += 1
            if time.monotonic() >= next_snap:
                scraper.snap(step)
                next_snap += interval
        while len(scraper.records) < cfg.min_snapshots:
            time.sleep(0.01)
            scraper.snap(step)
    finally:
        if server is not None:
            server.stop()
    failures = check_snapshots(
        scraper.records, tail_fraction=cfg.tail_fraction
    )
    return SoakResult(
        mode="train", steps=step, snapshots=scraper.records, failures=failures,
        summary={
            "faults_injected": n_faults,
            "evicted_hosts": sorted(fleet.evicted),
            "adapt": loop.summary(),
        },
    )


def _soak_serve(cfg: SoakConfig) -> SoakResult:
    import jax
    import numpy as np

    from ..configs import get_smoke_config
    from ..core.timers import TimerDB
    from ..models import model as M
    from ..monitor import MetricsExporter, MonitorServer, serving_payload
    from ..serving import Request, ServeSession, ServiceLevel

    db = TimerDB()
    arch = get_smoke_config("llama3.2-1b")
    params = M.init_params(arch, jax.random.PRNGKey(cfg.seed))
    prompt_len, max_new = 16, 6
    engine = ServeSession(
        arch, params,
        n_slots=4,
        max_seq=prompt_len + max_new + 8,
        block_size=8,
        slo=ServiceLevel(target_decode_ms=5.0, max_queue_delay_s=0.5),
        db=db,
    )
    loop = engine.control_loop
    exporter = MetricsExporter(
        db, control_loop=loop, serving_fn=serving_payload(engine)
    )
    server = None
    if cfg.scrape_http:
        server = MonitorServer(0, db, exporter=exporter,
                               serving_fn=serving_payload(engine))
        server.start()
    scraper = _Scraper(cfg, exporter, loop, server)

    rng = np.random.default_rng(cfg.seed)
    interval = _effective_interval(cfg)
    t0 = time.monotonic()
    deadline = t0 + cfg.budget_s
    next_snap = t0 + interval
    rid = 0
    try:
        while time.monotonic() < deadline:
            # seeded bursty open-loop traffic: keep a few requests queued so
            # the serving controller has pressure to act on
            burst = int(rng.integers(1, 4))
            while engine.queue_depth < burst:
                engine.submit(Request(
                    rid,
                    prompt=rng.integers(0, arch.vocab_size,
                                        int(rng.integers(4, prompt_len))).tolist(),
                    max_new_tokens=max_new,
                ))
                rid += 1
            engine.step()
            if time.monotonic() >= next_snap:
                scraper.snap(engine.stats()["steps"])
                next_snap += interval
        while len(scraper.records) < cfg.min_snapshots:
            time.sleep(0.01)
            scraper.snap(engine.stats()["steps"])
    finally:
        if server is not None:
            server.stop()
    failures = check_snapshots(
        scraper.records, tail_fraction=cfg.tail_fraction
    )
    stats = engine.stats()
    return SoakResult(
        mode="serve", steps=int(stats["steps"]), snapshots=scraper.records,
        failures=failures,
        summary={
            "submitted": rid,
            "completed": stats["completed"],
            "shed": stats["shed"],
            "adapt": loop.summary(),
        },
    )


def run_soak(cfg: SoakConfig) -> SoakResult:
    """Run one soak mode end to end; the result carries every snapshot record
    and the invariant failures (empty == pass)."""
    if cfg.mode == "train":
        result = _soak_train(cfg)
    elif cfg.mode == "serve":
        result = _soak_serve(cfg)
    else:
        raise ValueError(f"unknown soak mode {cfg.mode!r}")
    return result


def _report(result: SoakResult) -> None:
    ok = "ok  " if result.ok else "FAIL"
    print(
        f"[soak] {ok} {result.mode}: {result.steps} steps, "
        f"{len(result.snapshots)} snapshots, "
        f"{result.summary.get('adapt', {}).get('n_actions', 0)} adapt actions"
    )
    for failure in result.failures:
        print(f"[soak]   - {failure}", file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["train", "serve", "both"], default="train")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="wall-clock budget per mode (seconds)")
    ap.add_argument("--interval-s", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--fault-rate", type=float, default=0.03)
    ap.add_argument("--out-dir", default=None,
                    help="write each snapshot as <mode>_<idx>.prom here")
    ap.add_argument("--no-http", dest="http", action="store_false",
                    help="render in-process instead of scraping the monitor")
    args = ap.parse_args(argv)

    modes = ["train", "serve"] if args.mode == "both" else [args.mode]
    failures: list[str] = []
    for mode in modes:
        cfg = SoakConfig(
            mode=mode, budget_s=args.budget_s, interval_s=args.interval_s,
            seed=args.seed, n_hosts=args.hosts, n_micro=args.micro,
            fault_rate=args.fault_rate, scrape_http=args.http,
            out_dir=args.out_dir,
        )
        result = run_soak(cfg)
        _report(result)
        failures += [f"{mode}: {f}" for f in result.failures]
    if failures:
        print(f"[soak] {len(failures)} FAILURE(S)", file=sys.stderr)
        return 1
    print("[soak] all invariants held")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
