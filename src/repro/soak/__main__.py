from .run import main

raise SystemExit(main())
