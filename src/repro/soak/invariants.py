"""Soak invariants: what must hold across a run's exporter snapshots.

Each snapshot is an externally scraped (or rendered) ``/metrics`` page, parsed
by the strict exposition parser.  The checks below are the ROADMAP's
long-run-boundedness contract, asserted on the *external* view — if a metric
is wrong on the wire, it is wrong here, no matter what the in-process state
says:

* every snapshot parses cleanly (collected during the run);
* the scrape monotonic clock strictly increases and no ``*_total`` series
  ever decreases or disappears (monotonic-clock anomalies / counter resets);
* every ADAPT action recorded by the control loop is visible as a metrics
  delta: at each snapshot the scraped ``*_adapt_actions_total`` series equal
  the loop's decision log taken just before the scrape, series-for-series;
* cardinality is flat over the steady tail: timers, timer-tree series,
  parent-stats buckets, and counter channels stop growing once injected
  faults have settled, and parent-stats / pending-list sizes respect their
  design caps throughout.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from ..core.clocks import _PENDING_FOLD_CAP
from ..core.timers import PARENT_STATS_CAP
from ..monitor.promparse import Exposition

__all__ = ["SnapshotRecord", "check_snapshots"]


@dataclass
class SnapshotRecord:
    """One scraped exporter page plus the in-process truth captured
    immediately before the scrape (the delta baseline)."""

    index: int
    step: int
    source: str  # "http" | "render"
    #: control-loop ``{controller::action: count}`` taken just before scraping
    actions: dict[str, int] = field(default_factory=dict)
    exposition: Exposition | None = None
    parse_error: str | None = None
    path: str | None = None  # written .prom file, when out_dir is set

    def gauge(self, name: str, default: float = 0.0) -> float:
        try:
            return self.exposition.value(name)
        except (AttributeError, KeyError):
            return default


def _fail(failures: list[str], message: str) -> None:
    failures.append(message)


def check_snapshots(
    snapshots: list[SnapshotRecord],
    *,
    namespace: str = "repro",
    tail_fraction: float = 0.25,
) -> list[str]:
    """Run every invariant over the snapshot sequence; returns failures."""
    failures: list[str] = []
    ns = namespace
    if len(snapshots) < 2:
        _fail(failures, f"need >= 2 snapshots to check invariants, got {len(snapshots)}")
        return failures

    # -- 1. exposition validity ------------------------------------------------
    for snap in snapshots:
        if snap.parse_error is not None:
            _fail(failures, f"snapshot {snap.index}: malformed exposition: {snap.parse_error}")
    parsed = [s for s in snapshots if s.exposition is not None]
    if len(parsed) < 2:
        return failures

    # -- 2. monotonicity -------------------------------------------------------
    last_mono = None
    for snap in parsed:
        mono = snap.gauge(f"{ns}_scrape_monotonic_seconds")
        if last_mono is not None and mono <= last_mono:
            _fail(failures,
                  f"snapshot {snap.index}: monotonic clock went "
                  f"{last_mono:.6f} -> {mono:.6f}")
        last_mono = mono
    prev_totals: dict[tuple[str, Any], float] = {}
    for snap in parsed:
        totals = {
            key: v
            for key, v in snap.exposition.samples.items()
            if key[0].endswith("_total")
        }
        for key, prev_v in prev_totals.items():
            if key not in totals:
                _fail(failures,
                      f"snapshot {snap.index}: series {key[0]}{dict(key[1])} "
                      "disappeared")
            elif totals[key] < prev_v:
                _fail(failures,
                      f"snapshot {snap.index}: counter {key[0]}{dict(key[1])} "
                      f"decreased {prev_v} -> {totals[key]}")
        prev_totals = totals

    # membership epoch: when the fleet families are exported, the epoch may
    # only climb — a snapshot showing a lower epoch than its predecessor means
    # a membership record regressed (or a stale controller overwrote a newer
    # one), which breaks the transport's fencing contract
    epoch_metric = f"{ns}_fleet_membership_epoch"
    last_epoch = None
    for snap in parsed:
        if (epoch_metric, ()) not in snap.exposition.samples:
            continue
        epoch = snap.gauge(epoch_metric)
        if last_epoch is not None and epoch < last_epoch:
            _fail(failures,
                  f"snapshot {snap.index}: membership epoch regressed "
                  f"{last_epoch:g} -> {epoch:g}")
        last_epoch = epoch

    # -- 3. ADAPT external visibility ------------------------------------------
    metric = f"{ns}_adapt_actions_total"
    for snap in parsed:
        seen = {
            f"{dict(labels)['controller']}::{dict(labels)['action']}": v
            for labels, v in snap.exposition.series(metric).items()
        }
        for key, count in snap.actions.items():
            if seen.get(key) != float(count):
                _fail(failures,
                      f"snapshot {snap.index}: action {key} taken {count}x "
                      f"but metrics show {seen.get(key)}")
        for key, v in seen.items():
            if key not in snap.actions and v != 0.0:
                _fail(failures,
                      f"snapshot {snap.index}: metrics report {v:g}x {key} "
                      "the decision log never took")

    # -- 4. bounded cardinality over the steady tail ---------------------------
    for snap in parsed:
        buckets_max = snap.gauge(f"{ns}_timing_parent_stats_buckets_max")
        if buckets_max > PARENT_STATS_CAP:
            _fail(failures,
                  f"snapshot {snap.index}: parent-stats bucket count "
                  f"{buckets_max:g} exceeds the {PARENT_STATS_CAP} cap")
        pending_max = snap.gauge(f"{ns}_timing_counter_pending_max")
        if pending_max > _PENDING_FOLD_CAP:
            _fail(failures,
                  f"snapshot {snap.index}: counter pending list at "
                  f"{pending_max:g} exceeds the {_PENDING_FOLD_CAP} fold cap")
    tail = parsed[-max(2, int(len(parsed) * tail_fraction)):]
    for gauge_name in (
        f"{ns}_timing_timers",
        f"{ns}_timing_counter_channels",
        f"{ns}_timing_parent_stats_buckets",
    ):
        first, last = tail[0].gauge(gauge_name), tail[-1].gauge(gauge_name)
        if last > first:
            _fail(failures,
                  f"{gauge_name} grew over the steady tail: "
                  f"{first:g} -> {last:g} "
                  f"(snapshots {tail[0].index}..{tail[-1].index})")
    first_series = len(tail[0].exposition.series(f"{ns}_timer_windows_total"))
    last_series = len(tail[-1].exposition.series(f"{ns}_timer_windows_total"))
    if last_series > first_series:
        _fail(failures,
              f"timer-tree series grew over the steady tail: "
              f"{first_series} -> {last_series}")
    return failures
