from .export import TEXT_CONTENT_TYPE, MetricsExporter
from .promparse import Exposition, ExpositionError, parse_exposition
from .server import MonitorServer, StatusWriter, serving_payload

__all__ = [
    "Exposition",
    "ExpositionError",
    "MetricsExporter",
    "MonitorServer",
    "StatusWriter",
    "TEXT_CONTENT_TYPE",
    "parse_exposition",
    "serving_payload",
]
