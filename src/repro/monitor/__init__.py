from .server import MonitorServer, StatusWriter

__all__ = ["MonitorServer", "StatusWriter"]
