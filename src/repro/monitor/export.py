"""Prometheus/OpenMetrics export over the timer database.

The paper's premise is that timing data must be consumable *outside* the
process that recorded it; this module is that boundary for modern fleet
tooling: a :class:`MetricsExporter` renders the timer DB (plus whatever
adaptation state is wired in) as the classic text exposition format, either

* **pulled** — the monitor server serves it at ``GET /metrics``
  (``MonitorServer(..., exporter=...)``), or
* **pushed to disk** — :meth:`MetricsExporter.write_textfile` writes an atomic
  ``.prom`` file for the node_exporter textfile collector (clusters where an
  open port is not possible — same constraint :class:`StatusWriter` serves).

What is published (all under the ``repro_`` namespace):

* timer-tree nodes: inclusive/exclusive wall seconds and completed windows per
  node, labeled by scope path and the unique enclosing chain;
* ADAPT decision counts per ``controller::action`` (from the ``ADAPT/`` rows
  the control loop already writes into the DB) and checkpoint quarantines per
  reason;
* every counter channel, plus the checkpoint validation-failure counter under
  its conventional name (``*_validation_failures_total``);
* per-host straggler state when a detector is wired: cumulative step seconds,
  window counts, slowdown ratio, flagged/evicted flags;
* serving-engine stats (queue, slots, shed, KV-cache utilization) when a
  serving payload fn is wired; checkpoint-manager state when a checkpoint
  payload fn is wired;
* the exporter's own boundedness introspection (timer/bucket/channel/pending
  cardinality + parent-stats evictions) and scrape clocks — what the soak gate
  asserts stays flat/monotonic over a long run.

Rendered output always satisfies :func:`repro.monitor.promparse
.parse_exposition` — the render path validates names and escapes label values,
so a scope path containing ``"`` or a newline cannot ship a malformed page.
"""

from __future__ import annotations

import math
import os
import time
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

from ..core import clocks as _clocks
from ..core.timers import TimerDB, timer_db
from .promparse import _LABEL_RE, _METRIC_RE

__all__ = ["MetricFamily", "MetricsExporter", "TEXT_CONTENT_TYPE"]

#: the classic text exposition content type served at /metrics
TEXT_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

#: DB row prefixes the exporter decodes into labeled decision counters
_ADAPT_PREFIX = "ADAPT/"
_QUARANTINE_PREFIX = "CHECKPOINT/quarantine::"
#: the counter channel checkpoint validation failures are bumped on
_VALIDATION_CHANNEL = "ckpt_validation_failures"

#: serving stats() keys that are cumulative -> exported as counters
_SERVING_COUNTERS = {
    "completed": ("completed_total", "Requests finished"),
    "shed": ("shed_total", "Requests shed by SLO admission/queue control"),
    "steps": ("engine_steps_total", "Engine step() iterations"),
    "tokens": ("tokens_total", "Tokens decoded"),
}
#: serving stats() keys that are instantaneous -> exported as gauges
_SERVING_GAUGES = {
    "queue_depth": ("queue_depth", "Requests waiting for a decode slot"),
    "active_slots": ("active_slots", "Occupied decode slots"),
    "max_active": ("max_active_slots", "Current batch-width ceiling"),
    "occupancy": ("slot_occupancy_ratio", "Active slots / ceiling"),
    "throughput_tokens_per_s": (
        "throughput_tokens_per_second", "Decoded tokens per busy second"),
    "mean_latency_s": ("mean_latency_seconds", "Mean request latency"),
    "p95_latency_s": ("p95_latency_seconds", "p95 request latency"),
    "p95_ttft_s": ("p95_ttft_seconds", "p95 time to first token"),
    "kv_utilization": (
        "kv_utilization_ratio", "KV-cache blocks reserved / total"),
    "kv_high_water_blocks": (
        "kv_high_water_blocks", "Peak KV-cache blocks reserved"),
}


def _escape_label(value: str) -> str:
    return (
        value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _escape_help(value: str) -> str:
    return value.replace("\\", "\\\\").replace("\n", "\\n")


def _format_value(value: float) -> str:
    value = float(value)
    if math.isnan(value):
        return "NaN"
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    return repr(value)


@dataclass
class MetricFamily:
    """One metric family: name, type, help, and its ``(labels, value)`` rows."""

    name: str
    mtype: str  # "counter" | "gauge"
    help: str
    samples: list[tuple[dict[str, str], float]] = field(default_factory=list)

    def render(self) -> list[str]:
        if not _METRIC_RE.match(self.name):
            raise ValueError(f"invalid metric name {self.name!r}")
        if self.mtype == "counter" and not self.name.endswith("_total"):
            raise ValueError(f"counter {self.name!r} must be named *_total")
        lines = [
            f"# HELP {self.name} {_escape_help(self.help)}",
            f"# TYPE {self.name} {self.mtype}",
        ]
        for labels, value in self.samples:
            if labels:
                for key in labels:
                    if not _LABEL_RE.match(key) or key.startswith("__"):
                        raise ValueError(f"invalid label name {key!r}")
                body = ",".join(
                    f'{k}="{_escape_label(str(v))}"' for k, v in labels.items()
                )
                lines.append(f"{self.name}{{{body}}} {_format_value(value)}")
            else:
                lines.append(f"{self.name} {_format_value(value)}")
        return lines


class MetricsExporter:
    """Render the timer DB (+ wired adaptation state) as Prometheus metrics.

    Everything beyond ``db`` is optional wiring, mirroring
    :class:`~repro.monitor.server.MonitorServer`:

    control_loop:
        A :class:`repro.adapt.ControlLoop`; adds the poll counter (decision
        counts themselves come from the DB rows the loop writes, so they are
        exported even without this).
    detector:
        A :class:`repro.dist.stragglers.StragglerDetector`; adds the per-host
        families.
    serving_fn / checkpoint_fn / fleet_fn:
        The same payload callables the monitor endpoints use
        (``serving_payload(engine)`` / ``manager.status_payload`` /
        ``FleetController.status_payload``).
    """

    def __init__(
        self,
        db: TimerDB | None = None,
        *,
        namespace: str = "repro",
        control_loop=None,
        detector=None,
        serving_fn: Callable[[], dict[str, Any]] | None = None,
        checkpoint_fn: Callable[[], dict[str, Any]] | None = None,
        fleet_fn: Callable[[], dict[str, Any]] | None = None,
    ) -> None:
        if not _METRIC_RE.match(namespace):
            raise ValueError(f"invalid metric namespace {namespace!r}")
        self._db = db
        self.namespace = namespace
        self._control_loop = control_loop
        self._detector = detector
        self._serving_fn = serving_fn
        self._checkpoint_fn = checkpoint_fn
        self._fleet_fn = fleet_fn

    @property
    def db(self) -> TimerDB:
        return self._db if self._db is not None else timer_db()

    # -- collection ------------------------------------------------------------
    def collect(self) -> list[MetricFamily]:
        ns = self.namespace
        db = self.db
        families: list[MetricFamily] = []

        def add(name, mtype, help_, samples):
            families.append(MetricFamily(f"{ns}_{name}", mtype, help_, samples))

        # timer tree: one row per tree node; `chain` ('>'-joined ancestor
        # paths) keeps label sets unique when a shared timer splits under
        # several enclosing scopes
        inclusive, exclusive, windows = [], [], []
        todo = [((), node) for node in db.tree()]
        while todo:
            chain, node = todo.pop()
            labels = {"path": node.name, "chain": ">".join(chain)}
            inclusive.append((labels, node.inclusive))
            exclusive.append((dict(labels), node.exclusive))
            windows.append((dict(labels), float(node.count)))
            todo.extend((chain + (node.name,), c) for c in node.children)
        add("timer_inclusive_seconds", "gauge",
            "Accumulated wall seconds per timer-tree node (children included)",
            inclusive)
        add("timer_exclusive_seconds", "gauge",
            "Self wall seconds per timer-tree node (children subtracted)",
            exclusive)
        add("timer_windows_total", "counter",
            "Completed start/stop windows per timer-tree node", windows)

        # ADAPT decisions + checkpoint quarantines, decoded from the rows the
        # control plane publishes (external visibility needs only the DB)
        prefix = (
            f"{self._control_loop.prefix}/"
            if self._control_loop is not None
            else _ADAPT_PREFIX
        )
        actions, quarantines = [], []
        for timer in db.timers():
            name = timer.name
            if name.startswith(prefix) and "::" in name:
                controller, action = name[len(prefix):].split("::", 1)
                actions.append((
                    {"controller": controller, "action": action},
                    float(timer.count),
                ))
            elif name.startswith(_QUARANTINE_PREFIX):
                quarantines.append((
                    {"reason": name[len(_QUARANTINE_PREFIX):]},
                    float(timer.count),
                ))
        add("adapt_actions_total", "counter",
            "Control-plane decisions taken, per controller::action", actions)
        if self._control_loop is not None:
            add("adapt_polls_total", "counter",
                "Control-loop poll() calls",
                [({}, float(self._control_loop.polls))])
        add("checkpoint_quarantine_total", "counter",
            "Checkpoints quarantined at resume, per reason", quarantines)

        # counter channels (+ the ckptkit-conventional alias for validation
        # failures)
        names = _clocks.counter_names()
        values = _clocks.counter_values(names)
        add("counter_total", "counter",
            "Counter-channel totals (lock-free increment channels)",
            [({"channel": n}, v) for n, v in zip(names, values)])
        if _VALIDATION_CHANNEL in names:
            add("checkpoint_validation_failures_total", "counter",
                "Checkpoints that failed validation at resume scan",
                [({}, values[names.index(_VALIDATION_CHANNEL)])])

        if self._detector is not None:
            families.extend(self._collect_hosts())
        if self._serving_fn is not None:
            families.extend(self._collect_serving())
        if self._checkpoint_fn is not None:
            families.extend(self._collect_checkpoints())
        if self._fleet_fn is not None:
            families.extend(self._collect_fleet())

        # boundedness introspection + scrape clocks (the soak invariants)
        card = db.cardinality()
        cstats = _clocks.counter_stats()
        add("timing_timers", "gauge", "Timers in the database",
            [({}, float(card["timers"]))])
        add("timing_scope_handles", "gauge", "Cached scope handles",
            [({}, float(card["scope_handles"]))])
        add("timing_parent_stats_buckets", "gauge",
            "Parent-chain attribution buckets across all timers",
            [({}, float(card["parent_stats_buckets"]))])
        add("timing_parent_stats_buckets_max", "gauge",
            "Largest single timer's parent-chain bucket count",
            [({}, float(card["parent_stats_buckets_max"]))])
        add("timing_parent_stats_evictions_total", "counter",
            "Attribution buckets evicted at the per-timer LRU cap",
            [({}, float(card["parent_stats_evictions"]))])
        add("timing_counter_channels", "gauge", "Counter channels created",
            [({}, float(cstats["channels"]))])
        add("timing_counter_pending", "gauge",
            "Unfolded counter amounts across all pending lists",
            [({}, float(cstats["pending_total"]))])
        add("timing_counter_pending_max", "gauge",
            "Largest single channel's unfolded pending list",
            [({}, float(cstats["pending_max"]))])
        add("scrape_monotonic_seconds", "gauge",
            "time.monotonic() at collection (soak monotonicity probe)",
            [({}, time.monotonic())])
        add("scrape_walltime_seconds", "gauge",
            "time.time() at collection", [({}, time.time())])
        return families

    def _collect_hosts(self) -> list[MetricFamily]:
        ns = self.namespace
        det = self._detector
        stats = det.host_stats()
        report = det.reports[-1] if det.reports else None
        flagged = set(report.stragglers) if report is not None else set()
        seconds, windows, slowdown, flag_rows, evict_rows = [], [], [], [], []
        for host in range(det.n_hosts):
            labels = {"host": str(host)}
            count, total = stats.get(host, (0, 0.0))
            seconds.append((labels, total))
            windows.append((dict(labels), float(count)))
            if report is not None and host not in det.evicted:
                slowdown.append((dict(labels), report.slowdown(host)))
            flag_rows.append((dict(labels), float(host in flagged)))
            evict_rows.append((dict(labels), float(host in det.evicted)))
        return [
            MetricFamily(f"{ns}_host_step_seconds_total", "counter",
                         "Cumulative observed step seconds per host", seconds),
            MetricFamily(f"{ns}_host_windows_total", "counter",
                         "Step windows observed per host", windows),
            MetricFamily(f"{ns}_host_slowdown_ratio", "gauge",
                         "Host mean step time / fleet median (last report)",
                         slowdown),
            MetricFamily(f"{ns}_host_flagged", "gauge",
                         "1 when the last report flags the host as a straggler",
                         flag_rows),
            MetricFamily(f"{ns}_host_evicted", "gauge",
                         "1 when the host has been evicted", evict_rows),
        ]

    def _collect_serving(self) -> list[MetricFamily]:
        ns = self.namespace
        payload = self._serving_fn()
        engine = payload.get("engine", payload) if isinstance(payload, dict) else {}
        out: list[MetricFamily] = []
        for key, (suffix, help_) in _SERVING_COUNTERS.items():
            if key in engine:
                out.append(MetricFamily(
                    f"{ns}_serving_{suffix}", "counter", help_,
                    [({}, float(engine[key]))],
                ))
        for key, (suffix, help_) in _SERVING_GAUGES.items():
            if key in engine:
                out.append(MetricFamily(
                    f"{ns}_serving_{suffix}", "gauge", help_,
                    [({}, float(engine[key]))],
                ))
        return out

    def _collect_checkpoints(self) -> list[MetricFamily]:
        ns = self.namespace
        payload = self._checkpoint_fn() or {}
        checkpoints = payload.get("checkpoints", [])
        totals = payload.get("totals", {})
        out = [
            MetricFamily(f"{ns}_checkpoints_on_disk", "gauge",
                         "Valid checkpoints currently retained",
                         [({}, float(len(checkpoints)))]),
            MetricFamily(f"{ns}_checkpoints_quarantined", "gauge",
                         "Checkpoints moved aside as corrupt",
                         [({}, float(len(payload.get("quarantined", []))))]),
        ]
        if checkpoints:
            out.append(MetricFamily(
                f"{ns}_checkpoint_last_success_step", "gauge",
                "Step of the newest retained checkpoint",
                [({}, float(max(c["step"] for c in checkpoints)))],
            ))
        for key, suffix, help_ in (
            ("n_saves", "saves_total", "Checkpoint saves issued"),
            ("total_bytes", "write_bytes_total", "Checkpoint bytes written"),
            ("total_blocking_seconds", "blocking_seconds_total",
             "Seconds the training loop blocked on checkpoint writes"),
        ):
            if key in totals:
                out.append(MetricFamily(
                    f"{ns}_checkpoint_{suffix}", "counter", help_,
                    [({}, float(totals[key]))],
                ))
        return out

    def _collect_fleet(self) -> list[MetricFamily]:
        ns = self.namespace
        payload = self._fleet_fn() or {}
        hosts = payload.get("hosts", {})
        return [
            MetricFamily(f"{ns}_fleet_hosts", "gauge",
                         "Hosts currently in the fleet membership",
                         [({}, float(len(hosts)))]),
            MetricFamily(f"{ns}_fleet_membership_epoch", "gauge",
                         "Membership epoch (bumps on every join/leave; the "
                         "transport fence)",
                         [({}, float(payload.get("epoch", 0)))]),
            MetricFamily(f"{ns}_fleet_host_share", "gauge",
                         "Microbatches assigned per member host",
                         [({"host": str(h)}, float(e.get("share", 0)))
                          for h, e in hosts.items()]),
            MetricFamily(f"{ns}_fleet_joins_total", "counter",
                         "Hosts admitted mid-run",
                         [({}, float(payload.get("joins_total", 0)))]),
            MetricFamily(f"{ns}_fleet_leaves_total", "counter",
                         "Hosts removed on heartbeat expiry",
                         [({}, float(payload.get("leaves_total", 0)))]),
            MetricFamily(f"{ns}_fleet_reshard_defers_total", "counter",
                         "Membership changes skipped by the payback gate",
                         [({}, float(payload.get("reshard_defers_total", 0)))]),
            MetricFamily(f"{ns}_fleet_stale_samples_total", "counter",
                         "Samples rejected by the transport epoch fence",
                         [({}, float(payload.get("stale_samples_rejected", 0)))]),
        ]

    # -- output ----------------------------------------------------------------
    def render(self) -> str:
        """The full text exposition (always ends with a newline)."""
        lines: list[str] = []
        for family in self.collect():
            lines.extend(family.render())
        return "\n".join(lines) + "\n"

    def write_textfile(self, path: str) -> str:
        """Atomically write the exposition for the node_exporter textfile
        collector: render, write ``<path>.<pid>.tmp`` beside the target, then
        ``os.replace`` — a scraper never sees a half-written page."""
        body = self.render()
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(body)
        os.replace(tmp, path)
        return path
