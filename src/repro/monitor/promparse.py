"""Strict Prometheus text-exposition parser — the CI exposition-format gate.

Parses the classic text format (``text/plain; version=0.0.4``) with **no
external dependencies** and deliberately stricter rules than a scraping server
would apply, so a malformed metric name, label, escape, or duplicate series
fails the tier-1 suite (and the CI step over live soak snapshots) instead of
silently dropping data at scrape time:

* metric names must match ``[a-zA-Z_:][a-zA-Z0-9_:]*``; label names must match
  ``[a-zA-Z_][a-zA-Z0-9_]*`` and may not start with ``__`` (reserved);
* label values admit exactly the spec escapes ``\\\\``, ``\\"``, ``\\n``;
* every sample needs a preceding ``# TYPE`` for its family, declared once,
  with all of the family's samples contiguous (no interleaving);
* ``counter`` families must be named ``*_total`` (OpenMetrics rule, adopted);
* duplicate ``(name, label set)`` series are an error;
* only ``# HELP`` / ``# TYPE`` comment forms are allowed (the exporter emits
  nothing else, so anything else in a snapshot is corruption);
* the exposition must end with a newline.

Run as a module to gate snapshot files::

    PYTHONPATH=src python -m repro.monitor.promparse soak_snapshots/*.prom
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["Exposition", "ExpositionError", "main", "parse_exposition"]

_METRIC_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_TYPES = frozenset({"counter", "gauge", "histogram", "summary", "untyped"})
#: sample-name suffixes each complex type may add to its family name
_TYPE_SUFFIXES = {
    "histogram": ("", "_bucket", "_sum", "_count"),
    "summary": ("", "_sum", "_count"),
}

LabelSet = tuple[tuple[str, str], ...]


class ExpositionError(ValueError):
    """A violation of the text exposition format (line number included)."""

    def __init__(self, lineno: int, message: str) -> None:
        super().__init__(f"line {lineno}: {message}")
        self.lineno = lineno


@dataclass
class Exposition:
    """Parsed exposition: declared families and every sample, addressable by
    ``(metric name, sorted label items)``."""

    types: dict[str, str] = field(default_factory=dict)
    helps: dict[str, str] = field(default_factory=dict)
    samples: dict[tuple[str, LabelSet], float] = field(default_factory=dict)

    def value(self, name: str, **labels: str) -> float:
        """The sample value for an exact series; KeyError when absent."""
        return self.samples[(name, tuple(sorted(labels.items())))]

    def series(self, name: str) -> dict[LabelSet, float]:
        """All of one metric's series: ``{sorted label items: value}``."""
        return {
            labels: v for (n, labels), v in self.samples.items() if n == name
        }

    @property
    def n_samples(self) -> int:
        return len(self.samples)


def _family_of(name: str, types: dict[str, str]) -> str | None:
    """The declared family a sample name belongs to, or None."""
    if name in types:
        return name
    for family, mtype in types.items():
        for suffix in _TYPE_SUFFIXES.get(mtype, ()):
            if suffix and name == family + suffix:
                return family
    return None


def _parse_labels(lineno: int, text: str, pos: int) -> tuple[LabelSet, int]:
    """Parse ``{name="value",...}`` starting at ``text[pos] == '{'``; returns
    (sorted label items, index just past the closing brace)."""
    labels: list[tuple[str, str]] = []
    seen: set[str] = set()
    pos += 1  # past '{'
    n = len(text)
    while True:
        if pos >= n:
            raise ExpositionError(lineno, "unterminated label set")
        if text[pos] == "}":
            return tuple(sorted(labels)), pos + 1
        eq = text.find("=", pos)
        if eq < 0:
            raise ExpositionError(lineno, "label without '='")
        lname = text[pos:eq]
        if not _LABEL_RE.match(lname) or lname.startswith("__"):
            raise ExpositionError(lineno, f"invalid label name {lname!r}")
        if lname in seen:
            raise ExpositionError(lineno, f"repeated label {lname!r}")
        seen.add(lname)
        pos = eq + 1
        if pos >= n or text[pos] != '"':
            raise ExpositionError(lineno, f"label {lname!r} value not quoted")
        pos += 1
        out: list[str] = []
        while True:
            if pos >= n:
                raise ExpositionError(lineno, f"unterminated value for {lname!r}")
            ch = text[pos]
            if ch == "\\":
                if pos + 1 >= n:
                    raise ExpositionError(lineno, "dangling escape")
                esc = text[pos + 1]
                if esc == "\\":
                    out.append("\\")
                elif esc == '"':
                    out.append('"')
                elif esc == "n":
                    out.append("\n")
                else:
                    raise ExpositionError(lineno, f"invalid escape \\{esc}")
                pos += 2
            elif ch == '"':
                pos += 1
                break
            elif ch == "\n":
                raise ExpositionError(lineno, "raw newline in label value")
            else:
                out.append(ch)
                pos += 1
        labels.append((lname, "".join(out)))
        if pos < n and text[pos] == ",":
            pos += 1
        elif pos < n and text[pos] != "}":
            raise ExpositionError(lineno, "expected ',' or '}' after label")


def _parse_value(lineno: int, token: str) -> float:
    if not token:
        raise ExpositionError(lineno, "missing sample value")
    try:
        return float(token)
    except ValueError:
        raise ExpositionError(lineno, f"invalid sample value {token!r}") from None


def _unescape_help(lineno: int, text: str) -> str:
    out: list[str] = []
    i, n = 0, len(text)
    while i < n:
        ch = text[i]
        if ch == "\\":
            if i + 1 >= n:
                raise ExpositionError(lineno, "dangling escape in HELP")
            esc = text[i + 1]
            if esc == "\\":
                out.append("\\")
            elif esc == "n":
                out.append("\n")
            else:
                raise ExpositionError(lineno, f"invalid HELP escape \\{esc}")
            i += 2
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def parse_exposition(text: str) -> Exposition:
    """Parse (and strictly validate) one text exposition; raises
    :class:`ExpositionError` on the first violation."""
    if not text:
        raise ExpositionError(0, "empty exposition")
    if not text.endswith("\n"):
        raise ExpositionError(text.count("\n") + 1, "missing final newline")
    exp = Exposition()
    #: families whose sample block has ended (another family started since)
    closed: set[str] = set()
    current: str | None = None

    for lineno, line in enumerate(text.split("\n")[:-1], start=1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 2 and parts[1] == "HELP":
                if len(parts) < 3:
                    raise ExpositionError(lineno, "HELP without metric name")
                name = parts[2]
                if not _METRIC_RE.match(name):
                    raise ExpositionError(lineno, f"invalid metric name {name!r}")
                if name in exp.helps:
                    raise ExpositionError(lineno, f"duplicate HELP for {name}")
                exp.helps[name] = _unescape_help(
                    lineno, parts[3] if len(parts) > 3 else ""
                )
            elif len(parts) >= 2 and parts[1] == "TYPE":
                if len(parts) != 4:
                    raise ExpositionError(lineno, "TYPE needs: name + type")
                name, mtype = parts[2], parts[3]
                if not _METRIC_RE.match(name):
                    raise ExpositionError(lineno, f"invalid metric name {name!r}")
                if mtype not in _TYPES:
                    raise ExpositionError(lineno, f"unknown type {mtype!r}")
                if name in exp.types:
                    raise ExpositionError(lineno, f"duplicate TYPE for {name}")
                if name in closed or name == current:
                    raise ExpositionError(
                        lineno, f"TYPE for {name} after its samples"
                    )
                if mtype == "counter" and not name.endswith("_total"):
                    raise ExpositionError(
                        lineno, f"counter {name} must be named *_total"
                    )
                exp.types[name] = mtype
            else:
                raise ExpositionError(
                    lineno, f"unknown comment form {line[:40]!r}"
                )
            continue

        # -- sample line: name[{labels}] value [timestamp] ---------------------
        brace = line.find("{")
        space = line.find(" ")
        name_end = min(x for x in (brace, space, len(line)) if x >= 0)
        name = line[:name_end]
        if not _METRIC_RE.match(name):
            raise ExpositionError(lineno, f"invalid metric name {name!r}")
        family = _family_of(name, exp.types)
        if family is None:
            raise ExpositionError(lineno, f"sample {name} has no # TYPE")
        if family in closed:
            raise ExpositionError(
                lineno, f"samples for {family} are not contiguous"
            )
        if current is not None and current != family:
            closed.add(current)
        current = family

        pos = name_end
        labels: LabelSet = ()
        if pos < len(line) and line[pos] == "{":
            labels, pos = _parse_labels(lineno, line, pos)
        rest = line[pos:].split()
        if not rest or len(rest) > 2:
            raise ExpositionError(
                lineno, "expected: value [timestamp] after name/labels"
            )
        value = _parse_value(lineno, rest[0])
        if len(rest) == 2:
            try:
                int(rest[1])
            except ValueError:
                raise ExpositionError(
                    lineno, f"invalid timestamp {rest[1]!r}"
                ) from None
        key = (name, labels)
        if key in exp.samples:
            raise ExpositionError(
                lineno, f"duplicate series {name}{dict(labels)}"
            )
        exp.samples[key] = value
    return exp


def main(argv=None) -> int:
    """Gate: strictly parse each file; non-zero exit on the first violation."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", help="exposition snapshot files (.prom)")
    args = ap.parse_args(argv)
    status = 0
    for path in args.files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        try:
            exp = parse_exposition(text)
        except ExpositionError as exc:
            print(f"[promparse] FAIL {path}: {exc}")
            status = 1
        else:
            print(
                f"[promparse] ok   {path}: {len(exp.types)} families, "
                f"{exp.n_samples} samples"
            )
    return status


if __name__ == "__main__":
    raise SystemExit(main())
