"""Online application monitoring (paper Sec. 3.1: "a web-accessible HTTP
interface") — a stdlib HTTP server in a daemon thread serving the live timer
database, steerable parameters, and run status.

Endpoints:
    /            HTML overview (Fig.-2-style timer table + scope tree + the
                 serving queue/slot/shed rows when a serving engine is wired)
    /metrics     Prometheus text exposition (``text/plain; version=0.0.4``)
                 rendered by a :class:`repro.monitor.export.MetricsExporter` —
                 pass ``exporter=`` to enrich it with detector/control-loop
                 state; by default one is built over the database plus any
                 wired serving/checkpoint payload fns
    /timers      JSON timer snapshot
    /tree        nested JSON timer forest (inclusive/exclusive seconds per
                 scope, children recursively — repro.timing tree view)
    /params      JSON steerable parameters; POST /params {"name":..,"value":..}
                 steers a parameter live (paper Sec. 5 steering)
    /status      JSON run status (iteration, loss, checkpoint stats)
    /serving     JSON serving view: engine-level stats (queue depth, slot
                 occupancy, shed count, KV utilization) + per-request rows —
                 wire with ``serving_fn=engine.stats`` or the richer
                 ``serving_payload(engine)``
    /checkpoints JSON checkpoint view: on-disk checkpoints, retention policy,
                 quarantined (corrupt) entries with reasons, and the resume
                 plan the run started from — wire with
                 ``checkpoint_fn=manager.status_payload``
    /fleet       JSON fleet membership view: epoch, per-host weight/share/
                 stage/heartbeat age, join/leave/defer counters — wire with
                 ``fleet_fn=FleetController.status_payload``

Also provides :class:`StatusWriter`, which atomically writes the same payload to
a JSON file for clusters where an open port is not possible.
"""

from __future__ import annotations

import json
import os
import threading
from collections.abc import Callable
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from ..core.params import ParamRegistry, param_registry
from ..core.report import format_report, format_tree_report, tree_rows
from ..core.timers import TimerDB, timer_db
from .export import TEXT_CONTENT_TYPE, MetricsExporter


__all__ = ["MonitorServer", "StatusWriter", "serving_payload"]


def serving_payload(engine) -> Callable[[], dict[str, Any]]:
    """Build a ``serving_fn`` over a :class:`repro.serving.ServeSession`:
    engine-level stats plus the per-request rows, refreshed per scrape."""

    def payload() -> dict[str, Any]:
        return {"engine": engine.stats(), "requests": engine.request_stats()}

    return payload


def _serving_table(payload: dict[str, Any]) -> str:
    """Render the serving stats as report-style rows for the HTML overview."""
    engine = payload.get("engine", payload)
    width = max([len(k) for k in engine] + [len("serving row")]) + 2
    lines = ["Serving", "=" * (width + 14), f"{'serving row'.ljust(width)} {'value':>12}"]
    lines.append("-" * (width + 14))
    for key in sorted(engine):
        value = engine[key]
        shown = f"{value:12.4f}" if isinstance(value, float) else f"{value!s:>12}"
        lines.append(f"{key.ljust(width)} {shown}")
    return "\n".join(lines)


class StatusWriter:
    """Atomically writes run status + timer snapshot to a JSON file."""

    def __init__(self, path: str, db: TimerDB | None = None) -> None:
        self.path = path
        self._db = db if db is not None else timer_db()
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)

    def write(self, status: dict[str, Any]) -> None:
        payload = {"status": status, "timers": self._db.snapshot()}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)


class MonitorServer:
    """Threaded HTTP monitor.  Start with ``start()``; idempotent ``stop()``."""

    def __init__(
        self,
        port: int = 0,
        db: TimerDB | None = None,
        params: ParamRegistry | None = None,
        status_fn: Callable[[], dict[str, Any]] | None = None,
        serving_fn: Callable[[], dict[str, Any]] | None = None,
        checkpoint_fn: Callable[[], dict[str, Any]] | None = None,
        fleet_fn: Callable[[], dict[str, Any]] | None = None,
        exporter: MetricsExporter | None = None,
    ) -> None:
        self._db = db if db is not None else timer_db()
        self._params = params if params is not None else param_registry()
        self._status_fn = status_fn or (lambda: {})
        self._serving_fn = serving_fn
        self._checkpoint_fn = checkpoint_fn
        self._fleet_fn = fleet_fn
        self._exporter = (
            exporter
            if exporter is not None
            else MetricsExporter(
                self._db,
                serving_fn=serving_fn,
                checkpoint_fn=checkpoint_fn,
                fleet_fn=fleet_fn,
            )
        )
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._port = port

    @property
    def port(self) -> int:
        return self._httpd.server_address[1] if self._httpd else self._port

    def start(self) -> int:
        if self._httpd is not None:
            return self.port
        monitor = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence
                pass

            def _send(self, code: int, body: bytes, ctype: str = "application/json") -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                if self.path.startswith("/metrics"):
                    self._send(
                        200,
                        monitor._exporter.render().encode(),
                        TEXT_CONTENT_TYPE,
                    )
                elif self.path.startswith("/timers"):
                    self._send(200, json.dumps(monitor._db.snapshot()).encode())
                elif self.path.startswith("/tree"):
                    self._send(200, json.dumps(tree_rows(monitor._db)).encode())
                elif self.path.startswith("/params"):
                    self._send(200, json.dumps(monitor._params.describe()).encode())
                elif self.path.startswith("/status"):
                    self._send(200, json.dumps(monitor._status_fn()).encode())
                elif self.path.startswith("/serving"):
                    if monitor._serving_fn is None:
                        self._send(404, b'{"error": "no serving engine wired"}')
                    else:
                        self._send(200, json.dumps(monitor._serving_fn()).encode())
                elif self.path.startswith("/checkpoints"):
                    if monitor._checkpoint_fn is None:
                        self._send(404, b'{"error": "no checkpoint manager wired"}')
                    else:
                        self._send(
                            200, json.dumps(monitor._checkpoint_fn()).encode()
                        )
                elif self.path.startswith("/fleet"):
                    if monitor._fleet_fn is None:
                        self._send(404, b'{"error": "no fleet controller wired"}')
                    else:
                        self._send(200, json.dumps(monitor._fleet_fn()).encode())
                elif self.path == "/" or self.path.startswith("/index"):
                    sections = [format_report(monitor._db), format_tree_report(monitor._db)]
                    if monitor._serving_fn is not None:
                        sections.append(_serving_table(monitor._serving_fn()))
                    body = (
                        "<html><body><pre>"
                        + "\n\n".join(sections)
                        + "</pre></body></html>"
                    )
                    self._send(200, body.encode(), "text/html")
                else:
                    self._send(404, b'{"error": "not found"}')

            def do_POST(self):
                if not self.path.startswith("/params"):
                    self._send(404, b'{"error": "not found"}')
                    return
                length = int(self.headers.get("Content-Length", "0"))
                try:
                    req = json.loads(self.rfile.read(length) or b"{}")
                    monitor._params.set(req["name"], req["value"])
                    self._send(200, b'{"ok": true}')
                except Exception as exc:  # noqa: BLE001 - report to client
                    self._send(400, json.dumps({"error": str(exc)}).encode())

        self._httpd = ThreadingHTTPServer(("127.0.0.1", self._port), Handler)
        self._thread = threading.Thread(target=self._httpd.serve_forever, daemon=True)
        self._thread.start()
        return self.port

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
            self._thread = None
