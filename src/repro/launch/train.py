"""Training launcher: the scheduler-driven loop with the full timing
infrastructure, a unified runtime-adaptation control plane, restart, and
monitoring.

This is the production driver (examples/train_llm.py calls ``run_training``):
every lifecycle phase is a scheduled routine in a Cactus-style bin, so the
timer database holds a complete profile with zero manual instrumentation.  All
runtime adaptation goes through ONE :class:`repro.adapt.ControlLoop` polled
from the ANALYSIS bin: AdaptCheck checkpoint admission (paper §3.2, via
:class:`repro.adapt.CheckpointControl`) and straggler response
(:class:`repro.adapt.StragglerResponse` over the cross-host step-time
reduction).  Every decision lands in the ``ADAPT/`` section of the timer
report.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..adapt import CheckpointControl, ControlLoop, StragglerResponse
from ..checkpoint import CheckpointManager
from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..core import (
    AdaptiveCheckpointController,
    AdaptiveCheckpointPolicy,
    RunState,
    TimerLogger,
    adapt_rows,
    bin_distribution,
    format_report,
    format_tree_report,
    param_registry,
    straggler_rows,
    timer_db,
    tree_rows,
)
from ..core.clocks import CounterClock, register_clock
from ..data import DataLoader, SyntheticConfig, SyntheticLM
from ..dist.meshutil import local_mesh
from ..dist.pipeline import MicrobatchPlan, StagePlan, phase_ticks
from ..dist.stragglers import StragglerDetector
from ..fleet.topology import stage_for_host
from ..models import model as M, pipeline as model_pipeline
from ..models.config import ArchConfig, ShapeConfig
from ..monitor import MetricsExporter, MonitorServer, StatusWriter
from ..optim import AdamWConfig, init_opt_state
from ..timing import TimingSession
from .steps import (
    make_pipeline_train_step,
    make_train_step,
    make_transformer_pipeline_train_step,
    rules_for,
)

__all__ = ["TrainSettings", "run_training", "main"]


@dataclasses.dataclass
class TrainSettings:
    arch: str = "llama3.2-1b"
    smoke: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    mesh_shape: tuple = (1, 1)
    peak_lr: float = 1e-3
    ckpt_dir: str | None = None
    ckpt_mode: str = "adaptive"          # "adaptive" | "fixed" | "off"
    ckpt_every: int = 512                # fixed mode
    ckpt_max_fraction: float = 0.05      # adaptive mode
    ckpt_max_interval_s: float = 60.0
    ckpt_synchronous: bool = False
    ckpt_delay_s: float = 0.0            # injected write latency (experiments)
    ckpt_keep_n: int = 3                 # retention: keep the newest N
    ckpt_keep_every_k: int = 0           # retention: also keep step % k == 0
    #: preemption grace period: SIGTERM triggers a deadline-bounded emergency
    #: save through the manager's chained handler (None installs no deadline)
    save_deadline_s: float | None = None
    queue_seconds: float | None = None
    eval_every: int = 0
    report_every: int = 25
    log_path: str | None = None
    status_path: str | None = None
    monitor_port: int | None = None
    #: Prometheus textfile-collector path: the exporter page is atomically
    #: rewritten on the report cadence and at shutdown (node_exporter style)
    metrics_textfile: str | None = None
    restore: bool = True
    seed: int = 0
    data_mode: str = "copy"
    #: LR-schedule horizon; decoupled from `steps` so an interrupted run and
    #: its resumption share the same schedule (restart determinism)
    lr_total_steps: int | None = None
    #: pipeline-parallel (1F1B) training path: 0 = off; N > 0 shards stages
    #: over an N-way "pod" mesh axis (N must not exceed visible devices; the
    #: CPU smoke path uses 1 and still runs the full tick schedule)
    pipeline_stages: int = 0
    pipeline_layers: int = 8          # homogeneous stage-stack depth (MLP path)
    pipeline_micro: int = 4           # 1F1B microbatch count
    pipeline_width: int = 32          # stage activation width (MLP path)
    #: pipeline the real transformer (cfg's block stack; one pattern period
    #: per slot, embed/head pinned to the end stages) instead of the
    #: synthetic residual-MLP stack
    pipeline_model: bool = False


def _flops_per_step(cfg: ArchConfig, tokens: int) -> float:
    _, active = M.param_counts(cfg)
    return 6.0 * active * tokens


def run_training(
    settings: TrainSettings,
    cfg: ArchConfig | None = None,
    control_loop: ControlLoop | None = None,
    session: TimingSession | None = None,
) -> dict[str, Any]:
    """Run the scheduled training loop; returns a summary dict.

    ``session`` supplies the whole timing stack (database + scheduler +
    control loop) as one :class:`repro.timing.TimingSession`; by default the
    launcher bundles one over the process-global database, so a bare call
    still profiles into ``timer_db()``.  ``control_loop`` remains the narrower
    injection point (e.g. extra custom controllers pre-registered, or to
    inspect the decision log afterwards) and is ignored when a session is
    passed — register controllers on ``session.control_loop`` instead.
    """
    sess = (
        session
        if session is not None
        else TimingSession(timer_db(), control_loop=control_loop)
    )
    db = sess.db
    registry = param_registry()
    sch = sess.scheduler
    st = RunState(max_iterations=settings.steps)
    # checkpoint-label convention: every save is labeled with the number of
    # optimizer updates applied — i.e. the next iteration to execute — so a
    # resume (`s.iteration = label; DataLoader(start_step=label)`) replays the
    # trajectory exactly.  The CHECKPOINT bin of iteration i runs *after*
    # EVOL applied update i, so its label is i + 1, never i.
    st["updates"] = 0

    if cfg is None:
        cfg = get_smoke_config(settings.arch) if settings.smoke else get_config(settings.arch)
    pipelined = settings.pipeline_stages > 0
    if pipelined:
        # the 1F1B path pipelines homogeneous stages over a dedicated pod axis
        mesh = local_mesh((settings.pipeline_stages,), ("pod",))
    else:
        mesh = local_mesh(settings.mesh_shape)
    rules = rules_for(cfg)
    shape = ShapeConfig("train_local", "train", settings.seq_len, settings.global_batch)

    registry.declare("ckpt.max_fraction", settings.ckpt_max_fraction, steerable=True,
                     doc="AdaptCheck wall-time fraction bound")
    registry.declare("ckpt.max_interval_s", settings.ckpt_max_interval_s, steerable=True,
                     doc="AdaptCheck max seconds between checkpoints")

    # --- thorn state shared across routines -------------------------------------
    manager = None
    logger = TimerLogger(settings.log_path) if settings.log_path else None
    status = StatusWriter(settings.status_path) if settings.status_path else None
    monitor = None
    if pipelined and not settings.pipeline_model:
        # the MLP pipeline path trains the residual-MLP stage stack, not the
        # transformer cfg: same 6 * active-params * tokens convention, with
        # the stack's actual parameter count (n_layers x 2 W x W matmuls)
        active = settings.pipeline_layers * 2 * settings.pipeline_width ** 2
        model_flops = 6.0 * active * settings.global_batch * settings.seq_len
    else:
        model_flops = _flops_per_step(cfg, settings.global_batch * settings.seq_len)

    # --- the control plane: one loop, every adaptation registered on it ----------
    ckpt_timer_name = "CHECKPOINT/adaptcheck::write"
    ckpt_write_scope = sess.scope_handle(ckpt_timer_name)
    loop = sess.control_loop
    policy = AdaptiveCheckpointPolicy(
        mode="adaptive" if settings.ckpt_mode == "adaptive" else "fixed",
        every_iterations=settings.ckpt_every,
        max_fraction=registry.get("ckpt.max_fraction"),
        max_interval_seconds=registry.get("ckpt.max_interval_s"),
        queue_seconds=settings.queue_seconds,
    )
    controller = AdaptiveCheckpointController(policy)
    ckpt_control = CheckpointControl(
        controller, ckpt_timer=ckpt_timer_name, registry=registry
    )
    ckpt_active = bool(settings.ckpt_dir) and settings.ckpt_mode != "off"
    if ckpt_active:
        loop.register(ckpt_control)

    def topology_meta() -> dict[str, Any]:
        """Topology block stamped into every checkpoint's metadata — what a
        resume into a *different* host/stage count re-apportions from."""
        if not pipelined:
            return {}
        return {
            "topology": {
                "n_layers": stage_plan.n_layers,
                "n_micro": settings.pipeline_micro,
                "stage_weights": {
                    int(k): float(v) for k, v in stage_plan.weights.items()
                },
            }
        }

    def current_state() -> dict[str, Any]:
        return {
            "params": st["params"],
            "opt_state": st["opt_state"],
            "data": st["loader"].state(),
        }

    def durable_save(step: int) -> float:
        """Checkpoint-before-evict: write AND wait until durable (the barrier
        contract — an eviction must never outrun its safety checkpoint)."""
        if manager is None:
            raise RuntimeError("no checkpoint manager bound")
        t0 = time.monotonic()
        with ckpt_write_scope:
            # labeled with the update count, not the adapt step: the barrier
            # fires post-EVOL, so the state on disk is the start-of-step state
            # for update `st["updates"]` (see adaptive_checkpoint)
            manager.save(
                st["updates"], current_state(),
                metadata={"reason": "before_evict", **topology_meta()},
            )
            manager.wait()
        return time.monotonic() - t0

    if ckpt_active:
        ckpt_control.bind_durable_save(durable_save)
    # single-process topology: this host feeds its own EVOL step timer into the
    # reduction; multi-host launchers hand the detector a transport instead and
    # every host publishes through it.  On the pipeline path the response
    # controller additionally owns the StagePlan, so a confirmed straggler
    # that owns a stage is answered by moving the stage boundary (restage)
    # before any microbatch derate.
    pipeline_units = (
        model_pipeline.check_pipelineable(cfg)
        if pipelined and settings.pipeline_model
        else settings.pipeline_layers
    )
    stage_plan = (
        StagePlan.equal(range(settings.pipeline_stages), pipeline_units)
        if pipelined
        else None
    )
    detector = StragglerDetector(n_hosts=1, db=db)
    loop.register(
        StragglerResponse(
            detector,
            MicrobatchPlan.equal([0], n_micro=1),
            check_every=8,
            local_feed=(0, "EVOL/trainer::train_step"),
            stage_plan=stage_plan,
            # stage ownership derived from membership coordinates, not
            # hard-coded: one live host on an S-stage pipeline owns stage 0
            # (the rest ride along in-process), and a multi-host launcher
            # passes its real membership through the same function
            stage_for_host=(
                stage_for_host([0], settings.pipeline_stages) if pipelined else None
            ),
            evict_barrier=ckpt_control.evict_barrier if ckpt_active else None,
        )
    )
    sch.attach_control_loop(loop, bin="ANALYSIS")
    # one exporter for both surfaces: the monitor's /metrics endpoint and the
    # optional textfile written on the report cadence
    exporter = MetricsExporter(
        db, control_loop=loop, detector=detector,
        checkpoint_fn=lambda: manager.status_payload() if manager is not None else {},
    )
    # training-event clock registered mid-run (the paper's extensibility path:
    # every timer picks it up from its next window) + lock-free channel cells
    # resolved once for the hot loop
    register_clock(
        "events",
        lambda: CounterClock("events", {"tokens": "count", "steps": "count"}),
    )
    bump_flops = sess.counter("xla_flops", absolute=True)
    bump_tokens = sess.counter("tokens", absolute=True)
    bump_steps = sess.counter("steps", absolute=True)

    # --- STARTUP ----------------------------------------------------------------
    def startup(s: RunState) -> None:
        nonlocal manager, monitor
        opt_cfg = AdamWConfig()
        horizon = settings.lr_total_steps or settings.steps
        if pipelined:
            # each schedule phase is a separately dispatched, synchronized
            # segment recorded under its own timing scope
            phase_handles = {
                name: sess.scope_handle(f"train/pipeline/{name}")
                for name in phase_ticks(settings.pipeline_micro,
                                        settings.pipeline_stages)
            }
            if settings.pipeline_model:
                built = make_transformer_pipeline_train_step(
                    cfg, mesh, stage_plan,
                    seq_len=settings.seq_len,
                    global_batch=settings.global_batch,
                    n_micro=settings.pipeline_micro,
                    rules=rules,
                    opt_cfg=opt_cfg,
                    peak_lr=settings.peak_lr, total_steps=max(horizon, 2),
                    warmup_steps=max(min(100, horizon // 10), 1),
                    seed=settings.seed,
                    phase_cb=lambda name: phase_handles[name],
                )
            else:
                built = make_pipeline_train_step(
                    mesh, stage_plan,
                    width=settings.pipeline_width,
                    vocab_size=cfg.vocab_size,
                    seq_len=settings.seq_len,
                    global_batch=settings.global_batch,
                    n_micro=settings.pipeline_micro,
                    opt_cfg=opt_cfg,
                    peak_lr=settings.peak_lr, total_steps=max(horizon, 2),
                    warmup_steps=max(min(100, horizon // 10), 1),
                    seed=settings.seed,
                    phase_cb=lambda name: phase_handles[name],
                )
            s["built"] = built
            s["exec"] = built.fn  # host-side: re-packs the live StagePlan
        else:
            built = make_train_step(
                cfg, mesh, rules, shape, opt_cfg=opt_cfg,
                peak_lr=settings.peak_lr, total_steps=max(horizon, 2),
                warmup_steps=max(min(100, horizon // 10), 1),
            )
            s["built"] = built
            # absolute-path scope: keeps the historical name while nesting
            # under the STARTUP driver routine in the tree report
            with sess.scope_handle("STARTUP/compile"):
                s["exec"] = built.fn.lower(
                    built.abstract_state["params"],
                    built.abstract_state["opt_state"],
                    *built.abstract_inputs,
                ).compile()

        source = SyntheticLM(
            SyntheticConfig(cfg.vocab_size, settings.seq_len, settings.global_batch,
                            mode=settings.data_mode, seed=settings.seed),
            arch=cfg,
        )
        start_step = 0
        restored = None
        if settings.ckpt_dir:
            manager = CheckpointManager(
                settings.ckpt_dir,
                keep_n=settings.ckpt_keep_n,
                keep_every_k=settings.ckpt_keep_every_k,
                synchronous=settings.ckpt_synchronous,
                delay_s=settings.ckpt_delay_s,
            )
            if settings.restore:
                restored = manager.restore_latest()
        if restored is not None:
            start_step, tree, meta = restored
            s["params"] = tree["params"]
            s["opt_state"] = tree["opt_state"]
            s.iteration = start_step
            s["updates"] = start_step
            topo = (meta or {}).get("topology")
            if (
                pipelined
                and topo
                and int(topo.get("n_layers", -1)) == stage_plan.n_layers
            ):
                # N->M topology restore: re-apportion the saved stage capacity
                # weights onto the *current* stage set.  The parameter stack is
                # flat per-layer, so adopting the retargeted weights in place
                # is all it takes — the next step's pack() splits the same
                # layers along the new boundaries.  (Manifest JSON stringifies
                # the stage keys; convert back.)
                saved = StagePlan(
                    n_layers=stage_plan.n_layers,
                    weights={
                        int(k): float(v)
                        for k, v in topo["stage_weights"].items()
                    },
                )
                adopted = saved.retarget(range(settings.pipeline_stages))
                stage_plan.weights.clear()
                stage_plan.weights.update(adopted.weights)
            print(f"[train] restored checkpoint at step {start_step}")
        else:
            with sess.scope_handle("STARTUP/init_params"):
                if pipelined:
                    s["params"] = built.init_params(
                        jax.random.PRNGKey(settings.seed)
                    )
                else:
                    s["params"] = M.init_params(cfg, jax.random.PRNGKey(settings.seed))
                s["opt_state"] = init_opt_state(AdamWConfig(), s["params"])
        # commit state to the mesh with the step's exact shardings (AOT path;
        # the pipeline path shards inside its shard_map'd tick runner)
        if built.in_shardings[0] is not None:
            s["params"] = jax.device_put(s["params"], built.in_shardings[0])
            s["opt_state"] = jax.device_put(s["opt_state"], built.in_shardings[1])
        s["loader"] = DataLoader(source, start_step=start_step)

        if manager is not None:
            # installed only once live state exists — a preemption mid-restore
            # has nothing durable to add anyway.  The label is the number of
            # optimizer updates applied so far, which is exact no matter where
            # in the scheduler cycle the signal lands.
            try:
                manager.install_sigterm_handler(
                    lambda: (st["updates"], current_state()),
                    deadline_s=settings.save_deadline_s,
                )
            except ValueError:
                pass  # not the main thread: signals unavailable, skip the hook

        ckpt_control.start_run(time.monotonic())
        if settings.monitor_port is not None:
            monitor = MonitorServer(settings.monitor_port, db, registry,
                                    status_fn=lambda: {"iteration": st.iteration},
                                    checkpoint_fn=(
                                        manager.status_payload
                                        if manager is not None else None
                                    ),
                                    exporter=exporter)
            port = monitor.start()
            print(f"[train] monitor at http://127.0.0.1:{port}/")
        registry.freeze()

    sch.schedule(startup, bin="STARTUP", thorn="driver")

    # --- PRESTEP: data ------------------------------------------------------------
    def fetch_data(s: RunState) -> None:
        batch = s["loader"].next()
        shardings = s["built"].in_shardings[2]

        def put(k, v):
            if v.dtype == np.float32:  # modality stubs arrive f32 -> bf16
                v = jnp.asarray(v, jnp.bfloat16)
            return jax.device_put(v, shardings[k])

        s["batch"] = {k: put(k, v) for k, v in batch.items()}

    sch.schedule(fetch_data, bin="PRESTEP", thorn="data")

    # --- EVOL: the jitted step -----------------------------------------------------
    def train_step(s: RunState) -> None:
        params, opt_state, metrics = s["exec"](s["params"], s["opt_state"], s["batch"])
        metrics = jax.block_until_ready(metrics)
        s["params"], s["opt_state"] = params, opt_state
        s["updates"] = s.iteration + 1
        s["metrics"] = {k: float(v) for k, v in metrics.items()}
        bump_flops(model_flops)
        bump_tokens(float(s["built"].tokens_per_call))
        bump_steps(1.0)

    sch.schedule(train_step, bin="EVOL", thorn="trainer")

    # --- ANALYSIS: the control plane ---------------------------------------------
    # (attached above: the ControlLoop polls every registered controller from
    # the ANALYSIS bin — AdaptCheck steering + decision, straggler reduction +
    # response — and records each decision as an ADAPT/ row)

    # --- CHECKPOINT: consume the AdaptCheck admission ------------------------------
    def adaptive_checkpoint(s: RunState) -> None:
        if manager is None or not ckpt_active:
            return
        # decision was made (with live-steered policy) at this iteration's
        # ANALYSIS poll; this routine only performs the admitted write
        decision = ckpt_control.take_decision()
        s["last_ckpt_decision"] = decision
        if decision is None or not decision.checkpoint:
            return
        with ckpt_write_scope:
            stats = manager.save(
                s["updates"],
                current_state(),
                metadata={"reason": decision.reason, **topology_meta()},
            )
        ckpt_control.observe_checkpoint(stats["blocking_seconds"], stats["nbytes"])

    sch.schedule(adaptive_checkpoint, bin="CHECKPOINT", thorn="adaptcheck")

    # --- OUTPUT ------------------------------------------------------------------------
    def output(s: RunState) -> None:
        if logger is not None:
            logger.log(s.iteration, extra=s.get("metrics"))
        if status is not None:
            status.write({"iteration": s.iteration, **(s.get("metrics") or {})})
        if settings.report_every and s.iteration % settings.report_every == 0:
            if settings.metrics_textfile:
                exporter.write_textfile(settings.metrics_textfile)
            m = s.get("metrics") or {}
            print(
                f"[train] step {s.iteration:5d} loss={m.get('loss', float('nan')):.4f} "
                f"ce={m.get('ce', float('nan')):.4f} gnorm={m.get('grad_norm', 0):.2f}"
            )

    sch.schedule(output, bin="OUTPUT", thorn="report")

    # --- SHUTDOWN --------------------------------------------------------------------
    def shutdown(s: RunState) -> None:
        if manager is not None and settings.ckpt_mode != "off":
            with ckpt_write_scope:
                manager.save(
                    s["updates"],
                    current_state(),
                    metadata={"reason": "final", **topology_meta()},
                )
            manager.wait()
            manager.close()
        s["loader"].close()
        if settings.metrics_textfile:
            exporter.write_textfile(settings.metrics_textfile)
        if monitor is not None:
            monitor.stop()

    sch.schedule(shutdown, bin="SHUTDOWN", thorn="driver")

    # --- run -----------------------------------------------------------------------------
    # the session is entered for the duration of the run so every API that
    # defaults to timer_db() (scopes opened by thorns, reports, detectors)
    # lands in the session's database
    with sess:
        sch.run(st)

    summary = {
        "iterations": st.iteration,
        "final_metrics": st.get("metrics"),
        "total_seconds": db.get("simulation/total").seconds(),
        "bin_seconds": bin_distribution(db),
        "checkpoint": controller.summary() if controller else {},
        "ckpt_fraction": (
            ckpt_write_scope.seconds() / max(db.get("simulation/total").seconds(), 1e-9)
        ),
        # the resume picture the run started from (None on a cold start):
        # which checkpoints validated, which were quarantined and why
        "resume": (
            manager.last_resume_plan.summary()
            if manager is not None and manager.last_resume_plan is not None
            else None
        ),
        "straggler_reports": len(detector.reports),
        "straggler_rows": straggler_rows(detector),
        "adapt": loop.summary(),
        "adapt_rows": adapt_rows(loop),
        # the hierarchical profile: nested inclusive/exclusive rows derived
        # from the scope stack (simulation/total → bins → routines → scopes)
        "timer_tree": tree_rows(db),
    }
    if pipelined:
        summary["pipeline"] = {
            "n_stages": settings.pipeline_stages,
            "n_layers": stage_plan.n_layers,
            "workload": cfg.name if settings.pipeline_model else "mlp",
            "n_micro": settings.pipeline_micro,
            "depths": stage_plan.depths(),
            "phase_seconds": {
                name: (
                    db.get(f"train/pipeline/{name}").seconds()
                    if db.exists(f"train/pipeline/{name}")
                    else 0.0
                )
                for name in phase_ticks(settings.pipeline_micro,
                                        settings.pipeline_stages)
            },
        }
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-mode", choices=["adaptive", "fixed", "off"], default="adaptive")
    ap.add_argument("--ckpt-every", type=int, default=512)
    ap.add_argument("--ckpt-max-fraction", type=float, default=0.05)
    ap.add_argument("--ckpt-sync", action="store_true")
    ap.add_argument("--keep-n", type=int, default=3,
                    help="retention: keep the newest N checkpoints")
    ap.add_argument("--keep-every-k", type=int, default=0,
                    help="retention: additionally keep every k-th step (0 = off)")
    ap.add_argument("--save-deadline", type=float, default=None,
                    help="SIGTERM grace period (s) for the emergency save")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--report", action="store_true", help="print the timer report")
    ap.add_argument("--monitor-port", type=int, default=None)
    ap.add_argument("--metrics-textfile", default=None,
                    help="write the Prometheus exposition here on the report "
                         "cadence (textfile-collector scrape path)")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="1F1B pipeline-parallel path: pod-axis size (0 = off)")
    ap.add_argument("--pipeline-layers", type=int, default=8)
    ap.add_argument("--pipeline-micro", type=int, default=4)
    ap.add_argument("--pipeline-width", type=int, default=32)
    ap.add_argument("--pipeline-model", action="store_true",
                    help="pipeline the real transformer stack (one block-pattern "
                         "period per stage slot, embed/head pinned to the end "
                         "stages) instead of the synthetic residual-MLP stack")
    args = ap.parse_args(argv)

    settings = TrainSettings(
        arch=args.arch, smoke=args.smoke, steps=args.steps,
        global_batch=args.batch, seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        ckpt_mode=args.ckpt_mode, ckpt_every=args.ckpt_every,
        ckpt_max_fraction=args.ckpt_max_fraction,
        ckpt_synchronous=args.ckpt_sync, peak_lr=args.lr,
        ckpt_keep_n=args.keep_n, ckpt_keep_every_k=args.keep_every_k,
        save_deadline_s=args.save_deadline,
        monitor_port=args.monitor_port,
        metrics_textfile=args.metrics_textfile,
        pipeline_stages=args.pipeline_stages,
        pipeline_layers=args.pipeline_layers,
        pipeline_micro=args.pipeline_micro,
        pipeline_width=args.pipeline_width,
        pipeline_model=args.pipeline_model,
    )
    sess = TimingSession(timer_db())
    summary = run_training(settings, session=sess)
    print(json.dumps(summary, indent=1, default=str))
    if args.report:
        # fleet-health DIST/host rows and aggregate ADAPT/ counts are already
        # in the DB; the session's control loop supplies the decision log and
        # the tree report adds the hierarchical self-vs-children view
        print(format_report(
            sess.db, channels=("walltime", "cputime", "xla_flops"),
            adapt=sess.control_loop,
        ))
        print()
        print(format_tree_report(sess.db))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
