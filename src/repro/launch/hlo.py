"""Optimized-HLO analysis: collective operand bytes + op census.

``collective_bytes(hlo_text)`` sums operand sizes of every collective op in the
post-SPMD per-device module (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute and their async -start forms; -done forms are
skipped so nothing is double-counted).  Returns per-opcode byte totals — these
are *per-device* bytes; the roofline multiplies by chip count to match the
``collective_bytes / (chips × link_bw)`` convention (see benchmarks/roofline.py).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "op_census", "parse_sizes"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# "%name = type opcode(" — name may be %-prefixed or bare in new HLO syntax
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)"
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_sizes(hlo_text: str) -> dict[str, int]:
    """Instruction name -> output byte size (tuples summed)."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            sizes[name] = _type_bytes(type_str)
    return sizes


_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_NAME_TOKEN = re.compile(r"%?([\w.\-]+)")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-opcode summed operand bytes for collectives (per-device program)."""
    sizes = parse_sizes(hlo_text)
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, _type_str, opcode = m.groups()
        base = opcode
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base.endswith("-done"):
            continue  # operand is the matching -start; avoid double count
        if base not in _COLLECTIVES:
            continue
        # operands: the (...) group at the opcode's call site.  Match
        # "opcode(" — a bare find(opcode) would hit the *instruction name*
        # ("%all-reduce-start.1 = (...) all-reduce-start(...)") and sum the
        # async result-tuple type instead of the operands (2x the bytes).
        idx = line.find(opcode + "(")
        if idx < 0:
            continue
        rest = line[idx + len(opcode):]
        om = _OPERANDS_RE.search(rest)
        if not om:
            continue
        args = om.group(1)
        # modern HLO inlines operand types ("all-reduce(f32[2,64]{1,0} %x)"):
        # sum the inline shapes; otherwise fall back to name -> size lookup
        total = _type_bytes(args)
        if total == 0:
            for tok in args.split(","):
                nm = _NAME_TOKEN.match(tok.strip())
                if nm and nm.group(1) in sizes:
                    total += sizes[nm.group(1)]
        out[base] += total
    return dict(out)


def op_census(hlo_text: str, opcodes=("fusion", "dot", "convolution", "custom-call")) -> dict[str, int]:
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            counts[m.group(3)] += 1
    return {k: v for k, v in counts.items() if k in opcodes or k in _COLLECTIVES}
