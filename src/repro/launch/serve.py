"""Serving launcher: continuous-batching inference on the adapt control plane.

Requests stream through a :class:`repro.serving.ServeSession` — either all at
once (closed-loop drain) or as an open-loop Poisson arrival process
(``--arrival-rate``), the traffic shape production SLOs are judged under.
Batch-width and shedding decisions are taken by the ``ADAPT/serving``
controller on the session control loop and render in the report next to every
measured timer (paper §3.3: parameters "chosen dynamically from performance
measurements").

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
        --requests 32 --target-decode-ms 50 --arrival-rate 8 --report
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_smoke_config
from ..models import model as M
from ..monitor import MetricsExporter, serving_payload
from ..serving import Request, ServeSession, ServiceLevel
from ..timing import TimingSession

__all__ = ["main", "run_serving"]


def run_serving(
    arch: str = "llama3.2-1b",
    n_requests: int = 16,
    prompt_len: int = 32,
    max_new: int = 8,
    *,
    n_slots: int = 8,
    block_size: int = 16,
    target_decode_ms: float | None = None,
    max_queue_delay_s: float | None = None,
    arrival_rate: float | None = None,
    seed: int = 0,
    session: TimingSession | None = None,
) -> ServeSession:
    """Build a :class:`~repro.serving.ServeSession` and serve ``n_requests``.

    With ``arrival_rate`` (requests/second) the submissions follow an
    open-loop Poisson process driven against the wall clock — the engine keeps
    decoding in-flight requests between arrivals; otherwise everything is
    submitted upfront and drained.  Returns the engine (stats, request rows,
    and its control loop's decision log attached).
    """
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    engine = ServeSession(
        cfg, params,
        session=session,
        n_slots=n_slots,
        max_seq=prompt_len + max_new + 8,
        block_size=block_size,
        slo=ServiceLevel(
            target_decode_ms=target_decode_ms,
            max_queue_delay_s=max_queue_delay_s,
        ),
    )
    requests = [
        Request(rid, prompt=list(rng.integers(0, cfg.vocab_size, prompt_len)),
                max_new_tokens=max_new)
        for rid in range(n_requests)
    ]
    if arrival_rate is None:
        for req in requests:
            engine.submit(req)
        engine.run_until_idle()
        return engine

    offsets = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n_requests))
    t0 = time.monotonic()
    pending = list(zip(offsets, requests))
    while pending or engine.queue_depth or engine.active_slots:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            engine.submit(pending.pop(0)[1])
        engine.step()
        if pending and not engine.queue_depth and not engine.active_slots:
            # idle gap before the next arrival: sleep it off instead of spinning
            time.sleep(max(pending[0][0] - (time.monotonic() - t0), 0.0))
    return engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--target-decode-ms", type=float, default=None)
    ap.add_argument("--max-queue-delay", type=float, default=None,
                    help="SLO: shed queued requests past this estimated wait (s)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrivals (requests/s); default: drain")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--metrics-textfile", default=None,
                    help="write the final Prometheus exposition here "
                         "(textfile-collector scrape path)")
    args = ap.parse_args(argv)
    sess = TimingSession()
    with sess:
        engine = run_serving(
            args.arch, args.requests, args.prompt_len, args.max_new,
            n_slots=args.slots, block_size=args.block_size,
            target_decode_ms=args.target_decode_ms,
            max_queue_delay_s=args.max_queue_delay,
            arrival_rate=args.arrival_rate,
            session=sess,
        )
    print(json.dumps(engine.stats(), indent=1))
    if args.metrics_textfile:
        MetricsExporter(
            sess.db,
            control_loop=engine.control_loop,
            serving_fn=serving_payload(engine),
        ).write_textfile(args.metrics_textfile)
    if args.report:
        print(sess.report())
        print()
        print(sess.tree_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
