"""Serving launcher: batched inference through the ServingEngine with the
timing infrastructure + latency-steered batch size (paper §3.3 scenario).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --requests 32
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import ARCH_IDS, get_smoke_config
from ..core import format_report, format_tree_report, timer_db
from ..models import model as M
from ..serving import Request, ServingEngine
from ..timing import TimingSession

__all__ = ["main", "run_serving"]


def run_serving(
    arch: str = "llama3.2-1b",
    n_requests: int = 16,
    prompt_len: int = 32,
    max_new: int = 8,
    max_batch: int = 8,
    target_decode_ms: float | None = None,
    seed: int = 0,
    session: TimingSession | None = None,
):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    engine = ServingEngine(
        cfg, params, max_batch=max_batch,
        max_seq=prompt_len + max_new + 8,
        target_decode_ms=target_decode_ms,
        session=session,
    )
    for rid in range(n_requests):
        engine.submit(
            Request(rid, prompt=list(rng.integers(0, cfg.vocab_size, prompt_len)),
                    max_new_tokens=max_new)
        )
    engine.run()
    return engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--target-decode-ms", type=float, default=None)
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args(argv)
    sess = TimingSession(timer_db())
    engine = run_serving(
        args.arch, args.requests, args.prompt_len, args.max_new,
        args.max_batch, args.target_decode_ms, session=sess,
    )
    print(json.dumps(engine.stats(), indent=1))
    if args.report:
        print(format_report(sess.db))
        print()
        print(format_tree_report(sess.db))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
