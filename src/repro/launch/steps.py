"""Step builders: jitted train / prefill / serve steps with full sharding info.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct stand-ins
for every model input of a shape cell — shardable, no device allocation — used
by both the dry-run (lower+compile only) and the launchers (shapes for real
allocation).  ``decode_*``/``long_*`` cells lower ``serve_step`` (one new token
against a seq_len KV cache); ``prefill_*`` lowers the prefill; ``train_*``
lowers ``train_step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.context import use_sharding
from ..dist.pipeline import PipelineStep, StagePlan
from ..dist.sharding import DEFAULT_RULES, FSDP_RULES, ShardingRules, spec_for, tree_shardings
from ..models import model as M, pipeline as MP
from ..models.config import ArchConfig, ShapeConfig
from ..optim import AdamWConfig, adamw_update, init_opt_state, opt_state_axes, warmup_cosine
from ..timing import timed

__all__ = [
    "rules_for",
    "input_specs",
    "batch_axes",
    "make_train_step",
    "make_pipeline_train_step",
    "make_transformer_pipeline_train_step",
    "make_prefill_step",
    "make_serve_step",
    "shardings_for",
]


def rules_for(cfg: ArchConfig, overrides: dict[str, Any] | None = None) -> ShardingRules:
    rules = FSDP_RULES if cfg.sharding == "tp+fsdp" else DEFAULT_RULES
    if overrides:
        rules = rules.with_overrides(**overrides)
    return rules


def _bf16():
    return jnp.bfloat16


# ---------------------------------------------------------------------------
# Input specs per shape cell
# ---------------------------------------------------------------------------

def batch_axes(cfg: ArchConfig, kind: str) -> dict[str, tuple]:
    """Logical axes of each batch input."""
    axes: dict[str, tuple] = {}
    if kind in ("train",):
        axes["tokens"] = ("batch", "seq")
        axes["targets"] = ("batch", "seq")
    elif kind == "prefill":
        axes["tokens"] = ("batch", "seq")
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        axes["patch_embeds"] = ("batch", None, None)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        axes["src_frames"] = ("batch", "seq", None)
    return axes


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        text = s - cfg.n_vision_patches if cfg.family == "vlm" else s
        out: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, text), i32),
            "targets": jax.ShapeDtypeStruct((b, text), i32),
        }
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_patches, cfg.d_model), _bf16()
            )
        if cfg.family == "encdec":
            out["src_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _bf16())
        return out
    if shape.kind == "prefill":
        text = s - cfg.n_vision_patches if cfg.family == "vlm" else s
        if cfg.family == "encdec":
            # encode seq_len source frames; prefill the decoder with BOS
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "src_frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), _bf16()),
            }
        out = {"tokens": jax.ShapeDtypeStruct((b, text), i32)}
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_patches, cfg.d_model), _bf16()
            )
        return out
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": M.abstract_cache(cfg, b, s),
        }
    raise ValueError(f"unknown shape kind {shape.kind!r}")


# ---------------------------------------------------------------------------
# Sharding resolution
# ---------------------------------------------------------------------------

def shardings_for(axes_tree_, abstract_tree, mesh: Mesh, rules: ShardingRules):
    return tree_shardings(axes_tree_, abstract_tree, mesh, rules)


def _batch_shardings(cfg, shape, mesh, rules):
    specs = input_specs(cfg, shape)
    axes = batch_axes(cfg, shape.kind)
    out = {}
    for name, sds in specs.items():
        if name == "cache":
            out[name] = tree_shardings(
                M.cache_axes(cfg, shape.global_batch, shape.seq_len), sds, mesh, rules
            )
        else:
            ax = axes.get(name, ("batch",) + (None,) * (len(sds.shape) - 1))
            out[name] = NamedSharding(mesh, spec_for(ax, sds.shape, mesh, rules))
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

@dataclass
class BuiltStep:
    fn: Any                    # jitted function
    abstract_inputs: tuple     # positional abstract args (excluding params/opt)
    in_shardings: tuple
    out_shardings: Any
    abstract_state: dict[str, Any]  # {"params": ..., "opt_state": ...} abstract
    #: tokens consumed per invocation — launchers feed this into the "tokens"
    #: counter channel (one counter_cell bump per executed step)
    tokens_per_call: int = 0


# scope-aware decorator: nests under the caller's active scope (the
# STARTUP driver routine in launchers; bare in dry-runs)
@timed("steps::make_train_step")
def make_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardingRules,
    shape: ShapeConfig,
    opt_cfg: AdamWConfig | None = None,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10000,
    donate: bool = True,
    accum_steps: int = 1,
) -> BuiltStep:
    """``accum_steps > 1``: microbatched gradient accumulation — the global
    batch is split into microbatches scanned sequentially; activation memory
    scales down by the factor while FLOPs/collectives per token are unchanged
    (§Perf H3)."""
    p_axes = M.param_axes(cfg)
    p_abs = M.abstract_params(cfg)
    opt_cfg = opt_cfg if opt_cfg is not None else AdamWConfig()
    o_axes = opt_state_axes(opt_cfg, p_axes)
    o_abs = jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), p_abs)

    p_shard = tree_shardings(p_axes, p_abs, mesh, rules)
    o_shard = tree_shardings(o_axes, o_abs, mesh, rules)
    b_shard = _batch_shardings(cfg, shape, mesh, rules)

    def _grads(params, batch):
        grad_fn = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, batch), has_aux=True)
        (loss, metrics), grads = grad_fn(params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        with use_sharding(mesh, rules):
            lr = warmup_cosine(
                opt_state["step"], peak_lr=peak_lr, warmup_steps=warmup_steps,
                total_steps=total_steps,
            )
            if accum_steps > 1:
                micro = jax.tree.map(
                    lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                    batch,
                )

                def body(acc, mb):
                    g, m = _grads(params, mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32) / accum_steps, acc, g
                    )
                    return acc, m

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, metrics_seq = jax.lax.scan(body, zero, micro)
                metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_seq)
            else:
                grads, metrics = _grads(params, batch)
            params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state, lr)
            metrics = dict(metrics)
            metrics.update(stats)
            metrics["lr"] = lr
        return params, opt_state, metrics

    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return BuiltStep(
        fn=jitted,
        abstract_inputs=(input_specs(cfg, shape),),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        abstract_state={"params": p_abs, "opt_state": o_abs},
        tokens_per_call=shape.global_batch * shape.seq_len,
    )


@dataclass
class PipelineBuiltStep(BuiltStep):
    """A :class:`BuiltStep` whose ``fn`` drives the 1F1B pipeline schedule.

    ``fn`` is a host-side callable (not an AOT-compiled executable): the 1F1B
    schedule re-packs stage parameters from the live :class:`StagePlan` every
    step — that is what makes a run-time ``restage`` take effect on the very
    next step — and, when phase timing is on, dispatches
    warmup/steady/cooldown as separately synchronized segments.  The inner
    tick runner is jitted and cached per shape signature.
    """

    stage_plan: StagePlan | None = None
    pipeline: PipelineStep | None = None
    init_params: Any = None


@timed("steps::make_pipeline_train_step")
def make_pipeline_train_step(
    mesh: Mesh,
    stage_plan: StagePlan,
    *,
    axis: str = "pod",
    width: int = 32,
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    n_micro: int,
    opt_cfg: AdamWConfig | None = None,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10000,
    seed: int = 0,
    phase_cb: Any = None,
) -> PipelineBuiltStep:
    """Build the pipeline-parallel (1F1B) train step over mesh axis ``axis``.

    The model is a stack of ``stage_plan.n_layers`` homogeneous residual-MLP
    layers trained to map a token's (fixed, untrained) embedding to its
    next-token embedding — the homogeneous-stage workload the 1F1B schedule
    pipelines over the ``pod`` axis.  Layers are re-packed from the live
    ``stage_plan`` every step, so a straggler-triggered ``restage`` moves the
    stage boundaries for the next step without rebuilding anything.

    ``phase_cb(name)`` (a context-manager factory) times the schedule's
    warmup / steady / cooldown phases; launchers pass ``repro.timing`` scope
    handles.
    """
    opt_cfg = opt_cfg if opt_cfg is not None else AdamWConfig()
    n_layers = stage_plan.n_layers
    key = jax.random.PRNGKey(seed)
    k_emb, k_layers = jax.random.split(key)
    # fixed featurization: embeddings are not trained, the stage stack is
    emb = jax.random.normal(k_emb, (vocab_size, width), jnp.float32)
    emb = emb / jnp.sqrt(jnp.asarray(width, jnp.float32))
    alpha = 1.0 / float(max(n_layers, 1))

    def layer_fn(w, a):
        return a + jnp.tanh(a @ w[0]) @ w[1] * alpha

    def loss_fn(y, tgt):
        return jnp.mean((y - tgt) ** 2)

    pipeline = PipelineStep(
        layer_fn, loss_fn, mesh=mesh, axis=axis, n_micro=n_micro,
        phase_cb=phase_cb,
    )

    def init_params(init_key=None):
        k = init_key if init_key is not None else k_layers
        layers = jax.random.normal(k, (n_layers, 2, width, width), jnp.float32)
        return {"layers": layers * 0.3}

    p_abs = jax.eval_shape(init_params)
    o_abs = jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), p_abs)

    def train_fn(params, opt_state, batch):
        x = emb[batch["tokens"]]
        tgt = emb[batch["targets"]]
        packed, mask = stage_plan.pack(params["layers"])
        loss, packed_grads = pipeline(packed, x, tgt, stage_mask=mask)
        grads = {"layers": stage_plan.unpack(packed_grads)}
        lr = warmup_cosine(
            opt_state["step"], peak_lr=peak_lr, warmup_steps=warmup_steps,
            total_steps=total_steps,
        )
        params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state, lr)
        metrics = {"loss": loss, "lr": lr}
        metrics.update(stats)
        return params, opt_state, metrics

    replicated = NamedSharding(mesh, P())
    b_abs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    b_shard = {name: replicated for name in b_abs}
    return PipelineBuiltStep(
        fn=train_fn,
        abstract_inputs=(b_abs,),
        in_shardings=(None, None, b_shard),
        out_shardings=None,
        abstract_state={"params": p_abs, "opt_state": o_abs},
        tokens_per_call=global_batch * seq_len,
        stage_plan=stage_plan,
        pipeline=pipeline,
        init_params=init_params,
    )


@timed("steps::make_transformer_pipeline_train_step")
def make_transformer_pipeline_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    stage_plan: StagePlan,
    *,
    axis: str = "pod",
    seq_len: int,
    global_batch: int,
    n_micro: int,
    rules: ShardingRules | None = None,
    opt_cfg: AdamWConfig | None = None,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10000,
    seed: int = 0,
    phase_cb: Any = None,
) -> PipelineBuiltStep:
    """Build a 1F1B train step over the *real* transformer stack of ``cfg``.

    One pipeline slot runs one block-pattern period of the model
    (``models.pipeline``): the scanned stack is re-packed from the live
    ``stage_plan`` every step (a run-time ``restage`` moves stage boundaries
    on the next step), the token embedding is pinned to stage 0 and the
    final-norm + LM head + CE loss to the last stage via the schedule's
    ``first_fn``/``last_fn`` hooks, and the Pallas kernels selected by
    ``cfg.attn_impl``/``cfg.norm_impl`` dispatch inside the staged
    computation.  Stage-parameter specs compose the pipeline axis with the
    config's TP/FSDP rules (``models.pipeline.stage_param_specs``).
    """
    opt_cfg = opt_cfg if opt_cfg is not None else AdamWConfig()
    rules = rules if rules is not None else rules_for(cfg)
    n_units = MP.check_pipelineable(cfg)
    if stage_plan.n_layers != n_units:
        raise ValueError(
            f"stage_plan covers {stage_plan.n_layers} units but {cfg.name} "
            f"has {n_units} pattern periods ({cfg.n_layers} layers / "
            f"{len(cfg.block_pattern)}-block pattern)"
        )
    layer_fn, first_fn, last_fn = MP.make_stage_fns(cfg)
    stage_spec = MP.stage_param_specs(cfg, mesh, rules, axis)
    pipeline = PipelineStep(
        layer_fn, None, mesh=mesh, axis=axis, n_micro=n_micro,
        first_fn=first_fn, last_fn=last_fn, phase_cb=phase_cb,
        stage_spec=stage_spec,
    )

    def init_params(init_key=None):
        k = init_key if init_key is not None else jax.random.PRNGKey(seed)
        return M.init_params(cfg, k)

    p_abs = M.abstract_params(cfg)
    o_abs = jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), p_abs)

    def train_fn(params, opt_state, batch):
        stack, first, last = MP.split_params(cfg, params)
        packed, mask = stage_plan.pack(stack)
        loss, (packed_grads, first_grads, last_grads) = pipeline(
            packed, batch["tokens"], batch["targets"], stage_mask=mask,
            first_params=first, last_params=last,
        )
        grads = MP.merge_grads(
            cfg, stage_plan.unpack(packed_grads), first_grads, last_grads
        )
        lr = warmup_cosine(
            opt_state["step"], peak_lr=peak_lr, warmup_steps=warmup_steps,
            total_steps=total_steps,
        )
        params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state, lr)
        metrics = {"loss": loss, "lr": lr}
        metrics.update(stats)
        return params, opt_state, metrics

    replicated = NamedSharding(mesh, P())
    b_abs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    b_shard = {name: replicated for name in b_abs}
    return PipelineBuiltStep(
        fn=train_fn,
        abstract_inputs=(b_abs,),
        in_shardings=(None, None, b_shard),
        out_shardings=None,
        abstract_state={"params": p_abs, "opt_state": o_abs},
        tokens_per_call=global_batch * seq_len,
        stage_plan=stage_plan,
        pipeline=pipeline,
        init_params=init_params,
    )


@timed("steps::make_prefill_step")
def make_prefill_step(cfg: ArchConfig, mesh: Mesh, rules: ShardingRules, shape: ShapeConfig) -> BuiltStep:
    p_axes = M.param_axes(cfg)
    p_abs = M.abstract_params(cfg)
    p_shard = tree_shardings(p_axes, p_abs, mesh, rules)
    b, s = shape.global_batch, shape.seq_len
    c_abs = M.abstract_cache(cfg, b, s)
    c_shard = tree_shardings(M.cache_axes(cfg, b, s), c_abs, mesh, rules)
    b_shard = _batch_shardings(cfg, shape, mesh, rules)

    def prefill_step(params, batch, cache):
        with use_sharding(mesh, rules):
            return M.prefill(cfg, params, batch, cache)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(c_shard, None),
        donate_argnums=(2,),
    )
    return BuiltStep(
        fn=jitted,
        abstract_inputs=(input_specs(cfg, shape), c_abs),
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(c_shard, None),
        abstract_state={"params": p_abs},
        tokens_per_call=shape.global_batch * shape.seq_len,
    )


@timed("steps::make_serve_step")
def make_serve_step(cfg: ArchConfig, mesh: Mesh, rules: ShardingRules, shape: ShapeConfig) -> BuiltStep:
    p_axes = M.param_axes(cfg)
    p_abs = M.abstract_params(cfg)
    p_shard = tree_shardings(p_axes, p_abs, mesh, rules)
    b, s = shape.global_batch, shape.seq_len
    c_abs = M.abstract_cache(cfg, b, s)
    c_shard = tree_shardings(M.cache_axes(cfg, b, s), c_abs, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, spec_for(("batch", None), (b, 1), mesh, rules))

    def serve_step(params, cache, tokens):
        with use_sharding(mesh, rules):
            return M.decode_step(cfg, params, cache, tokens)

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(c_shard, None),
        donate_argnums=(1,),
    )
    return BuiltStep(
        fn=jitted,
        abstract_inputs=(c_abs, tok_abs),
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(c_shard, None),
        abstract_state={"params": p_abs},
        tokens_per_call=shape.global_batch,  # one new token per sequence
    )


def build_step(cfg: ArchConfig, mesh: Mesh, rules: ShardingRules, shape: ShapeConfig, **kw) -> BuiltStep:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, rules, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, rules, shape)
    return make_serve_step(cfg, mesh, rules, shape)
