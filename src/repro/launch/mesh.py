"""Production mesh construction (MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips, ("data","model").  Multi-pod:
2×16×16 = 512 chips, ("pod","data","model") — the leading "pod" axis maps to
the slower inter-pod links (DCN/ICI-over-optical); batch is sharded over
("pod","data") and cross-pod traffic is gradient reduction only (optionally
int8-compressed, optim/compression.py).
"""

from __future__ import annotations

from ..dist.compat import make_mesh

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, auto_axis_types=True)
