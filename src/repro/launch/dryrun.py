import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell against the production meshes and
record memory/cost/collective analysis for the roofline.

The two lines above MUST stay the first statements in this file: jax locks the
device count at first initialization, and the dry-run needs 512 placeholder
host devices so ``jax.make_mesh`` can build the 2×16×16 production mesh.  Do
NOT set this flag globally — smoke tests and benchmarks see 1 device.  It is
``setdefault``, not assignment, so a caller that already forced a smaller
topology (benchmarks/bench_roofline.py runs a mini 8-device dry-run) wins.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # full matrix
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, per-opcode collective operand bytes, and
sharding metadata.  benchmarks/roofline.py consumes these.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any

import jax

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import model as M
from ..models.config import SHAPES, shape_applicable
from .hlo import collective_bytes, op_census
from .mesh import make_production_mesh
from .steps import build_step, rules_for

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


def _mem_analysis(compiled) -> dict[str, Any]:
    out: dict[str, Any] = {}
    try:
        mem = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(mem, attr):
                out[attr] = int(getattr(mem, attr))
    except Exception as exc:  # noqa: BLE001 - backend may not implement
        out["error"] = str(exc)
    return out


def _cost_analysis(compiled) -> dict[str, float]:
    try:
        from ..dist.compat import cost_analysis

        cost = cost_analysis(compiled)
        return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    except Exception as exc:  # noqa: BLE001
        return {"error_msg": 0.0, "_error": str(exc)}  # type: ignore[dict-item]


def _sharded_nbytes(abstract_tree, shardings) -> int:
    """Per-device bytes of a sharded pytree (from NamedSharding shard shapes)."""
    import numpy as np

    total = 0
    for sds, sh in zip(jax.tree.leaves(abstract_tree), jax.tree.leaves(shardings)):
        shard_shape = sh.shard_shape(sds.shape)
        total += int(np.prod(shard_shape)) * sds.dtype.itemsize
    return total


def _compile_cell(cfg, shape, multi_pod, rules_overrides, step_kwargs=None, mesh=None):
    """Lower + compile; returns (compiled, built, mesh)."""
    from ..dist.sharding import ShardingRules  # noqa: F401 - typing aid

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, rules_overrides)
    built = build_step(cfg, mesh, rules, shape, **(step_kwargs or {}))
    with mesh:
        state_args = [built.abstract_state["params"]]
        if shape.kind == "train":
            state_args.append(built.abstract_state["opt_state"])
        lowered = built.fn.lower(*state_args, *built.abstract_inputs)
        compiled = lowered.compile()
    return compiled, built, mesh


def _cell_costs(compiled):
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    return cost, collective_bytes(hlo), hlo


def _scan_corrected(cfg, shape, multi_pod, rules_overrides, raw_cost, raw_coll,
                    step_kwargs=None, mesh=None):
    """Correct for XLA counting while(scan) bodies once, not × trip count.

    Compiles reduced-depth variants — one pattern period and zero layers
    (and, for enc-dec, a decoder-only variant) — and scales the per-period
    body delta by the scan trip count.  See EXPERIMENTS.md §Dry-run notes.
    """
    from ..models.model import _split_stack  # layer/period arithmetic

    n_scan, pattern, tail = _split_stack(cfg)
    p = len(pattern)
    variants = []  # (cfg_variant, multiplier applied to its body delta)
    if cfg.family == "encdec":
        c11 = cfg.replace(n_layers=p, n_enc_layers=1)
        c01 = cfg.replace(n_layers=p, n_enc_layers=0)
        c00 = cfg.replace(n_layers=0, n_enc_layers=0)
        cost11, coll11, _ = _cell_costs(_compile_cell(c11, shape, multi_pod, rules_overrides, step_kwargs, mesh)[0])
        cost01, coll01, _ = _cell_costs(_compile_cell(c01, shape, multi_pod, rules_overrides, step_kwargs, mesh)[0])
        cost00, coll00, _ = _cell_costs(_compile_cell(c00, shape, multi_pod, rules_overrides, step_kwargs, mesh)[0])
        deltas = [
            (_diff(cost11, cost01), _diff_coll(coll11, coll01), cfg.n_enc_layers - 1),
            (_diff(cost01, cost00), _diff_coll(coll01, coll00), n_scan - 1),
        ]
    else:
        c1 = cfg.replace(n_layers=p)
        c0 = cfg.replace(n_layers=0)
        cost1, coll1, _ = _cell_costs(_compile_cell(c1, shape, multi_pod, rules_overrides, step_kwargs, mesh)[0])
        cost0, coll0, _ = _cell_costs(_compile_cell(c0, shape, multi_pod, rules_overrides, step_kwargs, mesh)[0])
        deltas = [(_diff(cost1, cost0), _diff_coll(coll1, coll0), n_scan - 1)]

    corrected_cost = dict(raw_cost)
    corrected_coll = dict(raw_coll)
    bodies = []
    for dcost, dcoll, mult in deltas:
        bodies.append({"cost": dcost, "collectives": dcoll, "multiplier": mult})
        if mult <= 0:
            continue
        for key in ("flops", "transcendentals", "bytes accessed"):
            if key in corrected_cost and key in dcost:
                corrected_cost[key] = corrected_cost[key] + mult * dcost[key]
        for op, v in dcoll.items():
            corrected_coll[op] = corrected_coll.get(op, 0) + mult * v
    return corrected_cost, corrected_coll, bodies


def _diff(a: dict[str, float], b: dict[str, float]) -> dict[str, float]:
    return {k: a.get(k, 0.0) - b.get(k, 0.0) for k in set(a) | set(b) if not k.startswith("_")}


def _diff_coll(a: dict[str, int], b: dict[str, int]) -> dict[str, int]:
    return {k: a.get(k, 0) - b.get(k, 0) for k in set(a) | set(b)}


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    out_dir: str = ARTIFACT_DIR,
    rules_overrides: dict[str, Any] | None = None,
    variant: str = "baseline",
    arch_overrides: dict[str, Any] | None = None,
    verbose: bool = True,
    scan_correction: bool = True,
    step_kwargs: dict[str, Any] | None = None,
    smoke: bool = False,
    mesh=None,
    mesh_label: str | None = None,
    shape_override=None,
) -> dict[str, Any]:
    """Lower+compile one cell; write and return the artifact record.

    ``smoke``/``mesh``/``mesh_label``/``shape_override`` support reduced-scale
    dry-runs (benchmarks/bench_roofline.py): the SMOKE_CONFIG instead of the
    published shape, an explicit mesh instead of the production one, and a
    custom ShapeConfig — the artifact's ``mesh`` field carries the label so
    roofline.load_rows can select the mini matrix.
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if arch_overrides:
        cfg = cfg.replace(**arch_overrides)
    shape = shape_override if shape_override is not None else SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = mesh_label or ("multi" if multi_pod else "single")
    record: dict[str, Any] = {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh_name,
        "variant": variant,
        "status": "skipped",
    }
    if not ok:
        record["skip_reason"] = why
        _write(record, out_dir)
        if verbose:
            print(f"[dryrun] {arch} × {shape.name} × {mesh_name}: SKIP ({why})")
        return record

    t0 = time.monotonic()
    compiled, built, mesh = _compile_cell(
        cfg, shape, multi_pod, rules_overrides, step_kwargs, mesh
    )
    t_compile = time.monotonic() - t0

    mem = _mem_analysis(compiled)
    cost, coll, hlo = _cell_costs(compiled)
    census = op_census(hlo)

    if scan_correction:
        cost_corr, coll_corr, bodies = _scan_corrected(
            cfg, shape, multi_pod, rules_overrides, cost, coll, step_kwargs, mesh
        )
    else:
        cost_corr, coll_corr, bodies = cost, coll, []

    # analytic per-device state bytes from the shardings
    p_bytes = _sharded_nbytes(
        built.abstract_state["params"], built.in_shardings[0]
    )
    state_bytes = {"params_bytes_per_device": p_bytes}
    if shape.kind == "train":
        state_bytes["opt_bytes_per_device"] = _sharded_nbytes(
            built.abstract_state["opt_state"], built.in_shardings[1]
        )
    if shape.kind == "decode":
        state_bytes["cache_bytes_per_device"] = _sharded_nbytes(
            built.abstract_inputs[0], built.in_shardings[1]
        )

    total, active = M.param_counts(cfg)
    n_chips = mesh.devices.size
    record.update(
        {
            "status": "ok",
            "kind": shape.kind,
            "n_chips": n_chips,
            "seq_len": shape.seq_len,
            "global_batch": shape.global_batch,
            "tokens_per_step": shape.tokens_per_step,
            "params_total": total,
            "params_active": active,
            "memory_analysis": mem,
            "state_bytes": state_bytes,
            "cost_analysis_raw": cost,
            "cost_analysis": cost_corr,
            "collective_bytes_raw": coll,
            "collective_operand_bytes_per_device": coll_corr,
            "scan_bodies": bodies,
            "op_census": census,
            "compile_seconds": round(t_compile, 2),
            "sharding_preset": cfg.sharding,
            "accum_steps": int((step_kwargs or {}).get("accum_steps", 1)),
            "wall_seconds": round(time.monotonic() - t0, 2),
        }
    )
    _write(record, out_dir)
    if verbose:
        flops = cost_corr.get("flops", float("nan"))
        cbytes = sum(coll_corr.values())
        print(
            f"[dryrun] {arch} × {shape.name} × {mesh_name} [{variant}]: OK "
            f"flops/dev={flops:.3e} coll_bytes/dev={cbytes:.3e} "
            f"(compile {t_compile:.1f}s, total {record['wall_seconds']:.1f}s)"
        )
        print(f"  memory_analysis: {mem}")
    return record


def _write(record: dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if record.get("variant", "baseline") == "baseline" else f"__{record['variant']}"
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1)


def optimized_settings(arch: str, shape_name: str):
    """The EXPERIMENTS.md §Perf knobs per cell kind (``--preset optimized``).

    Returns (arch_overrides, rules_overrides, step_kwargs).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    arch_over: dict[str, Any] = {"embed_gather_constraint": True}
    rules_over: dict[str, Any] | None = None
    step_kwargs: dict[str, Any] | None = None
    if cfg.moe is not None:
        arch_over["moe_dispatch_mode"] = "tokens"
    if shape.kind == "train":
        arch_over.update({"loss_chunk": 512, "remat": "full"})
        step_kwargs = {"accum_steps": 8 if shape.global_batch % 8 == 0 else 1}
    if shape.kind == "decode" and cfg.n_kv_heads < 16:
        rules_over = {"kv_seq": "model"}
    return arch_over, rules_over, step_kwargs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true", help="full matrix")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--preset", choices=["baseline", "optimized"], default="baseline",
                    help="optimized = EXPERIMENTS.md §Perf knobs (variant 'opt')")
    args = ap.parse_args(argv)

    assert jax.device_count() >= 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()} — "
        "was jax initialized before the XLA_FLAGS line?"
    )

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                variant = "baseline" if args.preset == "baseline" else "opt"
                vsuffix = "" if variant == "baseline" else "__opt"
                suffix = f"{arch}__{shape}__{mesh_name}{vsuffix}.json"
                if args.skip_existing and os.path.exists(os.path.join(args.out, suffix)):
                    print(f"[dryrun] {suffix}: exists, skipping")
                    continue
                kwargs: dict[str, Any] = {}
                if args.preset == "optimized":
                    ao, ro, sk = optimized_settings(arch, shape)
                    kwargs = dict(arch_overrides=ao, rules_overrides=ro,
                                  step_kwargs=sk, variant="opt")
                try:
                    run_cell(arch, shape, mesh_name == "multi", out_dir=args.out, **kwargs)
                except Exception:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name))
                    print(f"[dryrun] {arch} × {shape} × {mesh_name}: FAILED")
                    traceback.print_exc()
                finally:
                    jax.clear_caches()  # keep the long matrix run bounded in RAM
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        return 1
    print("[dryrun] all requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
