"""Attention: GQA with causal / sliding-window masks, three implementations.

* ``naive``   — full (S×T) score matrix; the oracle (also ``kernels/flash_attention/ref.py``).
* ``chunked`` — memory-efficient exact attention: ``lax.scan`` over query
  chunks; each chunk computes an exact softmax against (a band of) K/V, so the
  S×T buffer never materializes.  This is the default everywhere and is what
  the dry-run lowers — the memory-roofline win is visible in ``cost_analysis``.
  For sliding-window attention only the K/V band covering the window is sliced
  per chunk (compute O(S·window) instead of O(S²)).
* ``pallas``  — the TPU flash-attention kernel (kernels/flash_attention);
  selected on TPU backends, falls back to ``chunked`` elsewhere.

Shapes: q (B, S, H, hd); k/v (B, T, KV, hd); GQA group = H // KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = ["attention", "decode_attention"]

_NEG_INF = -2.0e38


def _split_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, s, kv, g, hd = x.shape
    return x.reshape(b, s, kv * g, hd)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int | None) -> jax.Array:
    """(Sq, Tk) additive bias from position arrays."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-matrix reference attention."""
    b, s, h, hd = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    scale = hd ** -0.5
    qg = _split_heads(q, n_kv)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(s)
    k_pos = jnp.arange(t)
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return _merge_heads(out)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Exact attention, scanned over query chunks (no S×T buffer)."""
    b, s, h, hd = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    if s % chunk != 0 or s <= chunk:
        return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    n_chunks = s // chunk
    scale = hd ** -0.5
    qg = _split_heads(q, n_kv).reshape(b, n_chunks, chunk, n_kv, h // n_kv, hd)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, cq, KV, G, hd)

    # For sliding-window attention only a band of K/V is needed per q chunk.
    band = None
    if window is not None:
        band = min(t, ((window + chunk + 127) // 128) * 128)

    def body(_, inputs):
        qc, idx = inputs
        q_pos = q_offset + idx * chunk + jnp.arange(chunk)
        if band is not None and band < t:
            start = jnp.clip(idx * chunk - (band - chunk), 0, t - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
        else:
            kc, vc = k, v
            k_pos = jnp.arange(t)
        scores = jnp.einsum("bckgd,btkd->bkgct", qc, kc, preferred_element_type=jnp.float32) * scale
        ok = jnp.ones((chunk, k_pos.shape[0]), dtype=bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        scores = scores + jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgct,btkd->bckgd", probs.astype(vc.dtype), vc)
        return None, out

    _, outs = jax.lax.scan(body, None, (qg, jnp.arange(n_chunks)))
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_kv, h // n_kv, hd)
    return _merge_heads(outs)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    impl: str = "chunked",
    causal: bool = True,
    window: int | None = None,
    chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    if impl == "chunked":
        return chunked_attention(
            q, k, v, causal=causal, window=window, chunk=chunk, q_offset=q_offset
        )
    if impl == "pallas":
        from ..kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_valid: jax.Array,
) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, T, KV, hd); kv_valid: (B, T) bool.
    """
    b, _, h, hd = q.shape
    n_kv = k_cache.shape[2]
    scale = hd ** -0.5
    qg = _split_heads(q, n_kv)[:, 0]  # (B, KV, G, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32) * scale
    scores = jnp.where(kv_valid[:, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)
