"""Transformer stacks as 1F1B pipeline stages.

Adapts the real model (``models.model`` / ``models.blocks``) to
:class:`repro.dist.pipeline.PipelineStep`'s generalized schedule: the scanned
block stack becomes the homogeneous pipelined middle (one *pattern period* —
e.g. ``("rglru", "attn_local", "attn_local")`` — per pipeline slot), while the
token embedding and the final-norm + LM-head + loss are pinned to the first
and last stages via the schedule's ``first_fn`` / ``last_fn`` hooks.  The
Pallas kernels (flash attention, fused rmsnorm, rglru scan, wkv6) dispatch
inside the staged computation exactly as in the non-pipelined forward —
``cfg.attn_impl`` / ``cfg.norm_impl`` select them per config.

The contract mirrors ``models.model.loss_fn``: with every target valid and
equal-size microbatches, the 1F1B loss/grads match the non-pipelined
reference (tier-1 pins this at 1e-5 in f32).

Constraints checked by :func:`check_pipelineable`:
  * plain decoder LM (no encoder stack, no vision prefix),
  * dense blocks (``cfg.moe is None`` — the MoE aux loss is not plumbed
    through the per-stage loss accumulation),
  * ``n_layers`` divisible by the pattern length (no unrolled tail — every
    slot runs the same unit function),
  * ``cfg.loss_chunk`` unused here (the head sees one microbatch at a time).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..dist.sharding import ShardingRules, spec_for
from . import model as M
from .blocks import norm
from .config import ArchConfig
from .layers import rotary_embedding
from .model import _ce_terms, _embed_tokens, _logits, _unit_apply, decoder_pattern

__all__ = [
    "check_pipelineable",
    "make_stage_fns",
    "split_params",
    "merge_grads",
    "stage_param_specs",
]


def check_pipelineable(cfg: ArchConfig) -> int:
    """Validate ``cfg`` for stage pipelining; returns the unit (slot) count."""
    if cfg.family in ("encdec", "vlm"):
        raise ValueError(
            f"family {cfg.family!r} is not pipelineable: encoder stacks / "
            f"vision prefixes need cross-stage inputs beyond the token stream"
        )
    if cfg.moe is not None:
        raise ValueError(
            "MoE configs are not pipelineable: the load-balance aux loss is "
            "not plumbed through the per-stage loss accumulation"
        )
    pattern = decoder_pattern(cfg)
    if cfg.n_layers % len(pattern) != 0:
        raise ValueError(
            f"n_layers={cfg.n_layers} not divisible by pattern "
            f"{pattern} (len {len(pattern)}): the unrolled tail has no slot"
        )
    n_units = cfg.n_layers // len(pattern)
    if n_units < 1:
        raise ValueError(f"need at least one pattern period, got {n_units}")
    return n_units


def make_stage_fns(cfg: ArchConfig, *, z_coef: float = 1e-4):
    """(layer_fn, first_fn, last_fn) for :class:`PipelineStep`.

    ``layer_fn(unit_params, h)`` applies one pattern period (the per-slot
    parameters are one slice of the model's ``scan`` tuple); ``first_fn``
    embeds a raw token microbatch; ``last_fn`` runs final norm + logits +
    the per-microbatch CE (+ z) loss — the same terms as
    ``models.model.loss_fn`` with ``aux_coef`` irrelevant (dense blocks).
    """
    check_pipelineable(cfg)
    pattern = decoder_pattern(cfg)

    def layer_fn(unit_p, h):
        s = h.shape[1]
        rope = rotary_embedding(jnp.arange(s), cfg.resolved_head_dim, cfg.rope_theta)
        h, _, _ = _unit_apply(
            cfg, pattern, unit_p, h, rope=rope, mode="train", unit_cache=None,
            pos=None, enc_out=None, causal=True,
        )
        return h

    def first_fn(fp, tokens):
        return _embed_tokens(cfg, fp, tokens)

    def last_fn(lp, h, targets):
        h = norm(cfg, h, lp["final_norm"])
        logits = _logits(cfg, lp, h)
        ce_sum, z_sum, n_valid = _ce_terms(cfg, logits, targets, z_coef)
        return (ce_sum + z_sum) / jnp.maximum(n_valid, 1.0)

    return layer_fn, first_fn, last_fn


def split_params(cfg: ArchConfig, params) -> tuple[Any, Any, Any]:
    """Split full model params into (stack, first_params, last_params).

    ``stack`` is the scanned unit tuple (leading dim = unit count) that
    :meth:`StagePlan.pack` pads into slots; ``first_params`` feeds the
    pinned embedding; ``last_params`` feeds the pinned head (the embed table
    rides along when embeddings are tied — its two gradient contributions
    are summed back in :func:`merge_grads`).
    """
    first = {"embed": params["embed"]}
    last: dict[str, Any] = {"final_norm": params["final_norm"]}
    if cfg.tied_embeddings:
        last["embed"] = params["embed"]
    else:
        last["lm_head"] = params["lm_head"]
    return params["scan"], first, last


def merge_grads(cfg: ArchConfig, stack_grads, first_grads, last_grads):
    """Reassemble a full params-shaped gradient tree from the pipeline's
    (per-unit stack, first-stage, last-stage) gradient pieces."""
    embed = first_grads["embed"]
    if cfg.tied_embeddings:
        # tied table: gather grad (embedding) + matmul grad (head)
        embed = jax.tree.map(jnp.add, embed, last_grads["embed"])
    out: dict[str, Any] = {
        "embed": embed,
        "final_norm": last_grads["final_norm"],
        "scan": stack_grads,
        "tail": (),
    }
    if not cfg.tied_embeddings:
        out["lm_head"] = last_grads["lm_head"]
    return out


def stage_param_specs(
    cfg: ArchConfig, mesh: Mesh, rules: ShardingRules, axis: str = "pod"
):
    """``PartitionSpec`` pytree for the StagePlan-packed stack params.

    Every leaf's leading (slot) dimension maps to the pipeline ``axis``; the
    trailing parameter dimensions compose the model's logical axes through
    ``spec_for``'s TP/FSDP rules with the usual drop semantics — on a
    pod-only pipeline mesh every inner entry drops and the result is plain
    ``P(axis)`` (replicated within a stage, which is what the stage-local
    compute assumes).  Inner-axis sharding only takes effect on meshes that
    carry those axes, where the stage body must be collective-aware
    (ROADMAP follow-up).
    """
    axes = M.param_axes(cfg)["scan"]
    shapes = M.abstract_params(cfg)["scan"]

    def one(sds, ax):
        inner = spec_for(tuple(ax)[1:], tuple(sds.shape)[1:], mesh, rules)
        return P(axis, *inner)

    return jax.tree.map(one, shapes, axes)
