"""Mixture-of-Experts FFN: top-k token-choice routing with static capacity.

Expert-parallel design (DESIGN.md §3): expert-stacked weights carry the
``expert`` logical axis (sharded over the ``model`` mesh axis), and dispatch is
gather/scatter-based — tokens are packed into an (E, C) slot buffer with
``take``/scatter-add, *not* with GShard's dense one-hot dispatch einsums, so
HLO FLOPs reflect useful compute only.  Tokens beyond an expert's capacity
``C = ceil(top_k·S·cf/E)`` are dropped (their residual passes through), the
standard static-shape discipline.

Router math in f32; Switch-style load-balancing aux loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from .config import ArchConfig
from .layers import PSpec

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ArchConfig, stack: tuple[int, ...] = ()) -> dict[str, PSpec]:
    assert cfg.moe is not None
    d, e, de = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_expert
    lead = tuple(stack)
    lax_ = ("layers",) * len(stack)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "router": PSpec(lead + (d, e), lax_ + ("embed", "expert"), dtype=jnp.float32),
        "wi": PSpec(lead + (e, d, de), lax_ + ("expert", "embed", "expert_ffn"), dtype=dtype),
        "wg": PSpec(lead + (e, d, de), lax_ + ("expert", "embed", "expert_ffn"), dtype=dtype),
        "wo": PSpec(lead + (e, de, d), lax_ + ("expert", "expert_ffn", "embed"), dtype=dtype),
    }


def moe_apply(
    cfg: ArchConfig, p: dict[str, jax.Array], x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Routing groups: for S > 1 each batch row is its own routing group (keeps
    dispatch local to the batch shard).  For decode (S == 1) the *batch* is the
    token group — with per-row grouping every row would run all E experts at
    capacity 1, inflating FLOPs E/k-fold.  Batch-grouping instead produces the
    cross-device token shuffle that expert parallelism implies (XLA inserts the
    all-to-all).
    """
    b, s, d = x.shape
    if s == 1 and b > 1:
        y, aux = _moe_grouped(cfg, p, x.reshape(1, b, d))
        return y.reshape(b, 1, d), aux
    return _moe_grouped(cfg, p, x)


def _moe_grouped(
    cfg: ArchConfig, p: dict[str, jax.Array], x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Dispatch/combine are written per-row and ``vmap``ed over the batch, so
    every scatter/gather carries the batch as an *operand batch dimension* —
    SPMD partitions those along the (sharded) batch axis instead of replicating
    the full global buffer (§Perf H1: advanced-indexing scatters with explicit
    batch index arrays forced "involuntary full rematerialization" + 30 GB
    all-reduces of replicated (B_global, S, D) buffers)."""
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    cap = max(math.ceil(k * s * moe.capacity_factor / e), 1)
    cap = min(cap, s)

    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]), axis=-1
    )  # (B,S,E) f32
    top_v, top_i = jax.lax.top_k(gates, k)  # (B,S,k)
    top_v = top_v / jnp.maximum(jnp.sum(top_v, axis=-1, keepdims=True), 1e-9)

    def route_row(xr: jax.Array, tv: jax.Array, ti: jax.Array):
        """xr (S,D), tv/ti (S,k) -> (xe (E,C,D), token_idx (E,C), w_slot (E,C),
        mask (S,E))."""
        combine = jnp.zeros((s, e), jnp.float32)
        combine = combine.at[jnp.arange(s)[:, None], ti].add(tv)
        mask = (combine > 0).astype(jnp.int32)
        pos = jnp.cumsum(mask, axis=0) - 1
        keep = (mask == 1) & (pos < cap)
        slot = jnp.where(keep, pos, cap)  # (S,E); overflow slot sliced off
        token_idx = jnp.full((e, cap + 1), s, jnp.int32)
        token_idx = token_idx.at[
            jnp.broadcast_to(jnp.arange(e)[None, :], (s, e)), slot
        ].set(jnp.broadcast_to(jnp.arange(s)[:, None], (s, e)))
        token_idx = token_idx[:, :cap]  # (E,C); sentinel = s
        xp = jnp.concatenate([xr, jnp.zeros((1, d), xr.dtype)], axis=0)
        xe = xp[token_idx]  # (E,C,D)
        w_slot = combine[token_idx, jnp.arange(e)[:, None]]
        w_slot = jnp.where(token_idx < s, w_slot, 0.0)
        return xe, token_idx, w_slot, mask

    xe, token_idx, w_slot, expert_mask = jax.vmap(route_row)(x, top_v, top_i)
    if cfg.moe_dispatch_mode == "tokens":
        xe = constrain(xe, "batch", "expert", None, None)
    else:
        xe = constrain(xe, "batch", "expert", None, "embed")

    up = jnp.einsum("becd,edf->becf", xe, p["wi"])
    gate = jnp.einsum("becd,edf->becf", xe, p["wg"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
    ye = jnp.einsum("becf,efd->becd", hidden, p["wo"])
    if cfg.moe_dispatch_mode == "tokens":
        ye = constrain(ye, "batch", "expert", None, None)
    else:
        ye = constrain(ye, "batch", "expert", None, "embed")

    def combine_row(ye_r: jax.Array, ti_r: jax.Array, ws_r: jax.Array):
        # accumulate in the activation dtype: each token receives ≤ top_k adds
        # (distinct slots), and the EP combine all-reduce over the model axis
        # moves half the bytes vs f32 (§Perf H1 iter 3)
        y_pad = jnp.zeros((s + 1, d), x.dtype)
        y_pad = y_pad.at[ti_r].add((ye_r.astype(jnp.float32) * ws_r[..., None]).astype(x.dtype))
        return y_pad[:s]

    y = jax.vmap(combine_row)(ye, token_idx, w_slot)
    y = constrain(y, "batch", "seq", None)

    # Switch load-balancing loss: E * Σ_e f_e · p̄_e
    frac = jnp.mean(expert_mask.astype(jnp.float32), axis=(0, 1))  # (E,)
    prob = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac * prob)
    return y, aux
