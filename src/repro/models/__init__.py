from .config import ArchConfig, MoESettings, ShapeConfig, SHAPES
from . import model

__all__ = ["ArchConfig", "MoESettings", "ShapeConfig", "SHAPES", "model"]
