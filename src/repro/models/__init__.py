
from . import model
from .config import SHAPES, ArchConfig, MoESettings, ShapeConfig


__all__ = ["ArchConfig", "MoESettings", "ShapeConfig", "SHAPES", "model"]
