"""Per-kind layer blocks: attn / attn_local / xattn / rglru / rwkv.

Every block exposes three pieces:
  * ``block_specs(cfg, kind, stack)``        — PSpec tree for one layer,
  * ``block_cache_specs(cfg, kind, B, Smax)`` — PSpec tree for its decode cache,
  * ``block_apply(cfg, kind, p, h, ...)``     — forward in one of three modes:
      "train"   : full sequence, no cache,
      "prefill" : full sequence, returns a filled cache,
      "decode"  : one token against the cache (S == 1).

Cache design (DESIGN.md §3): global attention keeps (B, Smax, KV, hd) K/V
written at absolute positions; sliding-window attention keeps a **ring buffer**
of ``window`` slots plus per-slot absolute positions (this is what makes
``long_500k`` sub-quadratic for the hybrid arch); RG-LRU keeps the (B, D) f32
recurrence state and the (B, cw-1, D) conv tail; RWKV keeps the (B, H, K, V)
f32 WKV state and the two token-shift vectors.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from ..kernels.rglru import ops as rglru_ops
from ..kernels.rmsnorm.ops import rms_norm_fused
from ..kernels.rwkv6 import ops as rwkv_ops
from .attention import attention, decode_attention
from .config import ArchConfig
from .layers import PSpec, apply_rotary, gated_mlp, gated_mlp_specs, rms_norm, rotary_embedding
from .moe import moe_apply, moe_specs

__all__ = ["block_specs", "block_cache_specs", "block_apply", "norm"]

_RWKV_LORA = 64


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def norm(cfg: ArchConfig, x: jax.Array, weight: jax.Array) -> jax.Array:
    """Config-dispatched rmsnorm: the unfused reference or the Pallas fused
    kernel (``cfg.norm_impl == "fused"``; interpret-mode off-TPU).  Both sides
    compute in f32 and return ``x.dtype`` — identical dtype contract."""
    if cfg.norm_impl == "fused":
        return rms_norm_fused(x, weight, cfg.norm_eps)
    return rms_norm(x, weight, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ArchConfig, stack: tuple[int, ...], prefix_cross: bool = False) -> dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = _dtype(cfg)
    lead, lax_ = tuple(stack), ("layers",) * len(stack)
    specs: dict[str, Any] = {
        "ln": PSpec(lead + (d,), lax_ + (None,), init="ones", dtype=dt),
        "wq": PSpec(lead + (d, cfg.q_dim), lax_ + ("embed", "heads"), dtype=dt),
        "wk": PSpec(lead + (d, cfg.kv_dim), lax_ + ("embed", "kv_heads"), dtype=dt),
        "wv": PSpec(lead + (d, cfg.kv_dim), lax_ + ("embed", "kv_heads"), dtype=dt),
        "wo": PSpec(lead + (cfg.q_dim, d), lax_ + ("heads", "embed"), dtype=dt),
    }
    if cfg.use_qk_norm:
        specs["qn"] = PSpec(lead + (hd,), lax_ + (None,), init="ones", dtype=dt)
        specs["kn"] = PSpec(lead + (hd,), lax_ + (None,), init="ones", dtype=dt)
    return specs


def _ffn_specs(cfg: ArchConfig, stack: tuple[int, ...]) -> dict[str, Any]:
    lead, lax_ = tuple(stack), ("layers",) * len(stack)
    dt = _dtype(cfg)
    out: dict[str, Any] = {
        "ln2": PSpec(lead + (cfg.d_model,), lax_ + (None,), init="ones", dtype=dt)
    }
    if cfg.moe is not None:
        out["moe"] = moe_specs(cfg, stack)
    else:
        out["mlp"] = gated_mlp_specs(cfg.d_model, cfg.d_ff, dt, stack)
    return out


def _rglru_specs(cfg: ArchConfig, stack: tuple[int, ...]) -> dict[str, Any]:
    d = cfg.d_model
    dt = _dtype(cfg)
    lead, lax_ = tuple(stack), ("layers",) * len(stack)
    return {
        "ln": PSpec(lead + (d,), lax_ + (None,), init="ones", dtype=dt),
        "w_in": PSpec(lead + (d, d), lax_ + ("embed", "ffn"), dtype=dt),
        "w_gate": PSpec(lead + (d, d), lax_ + ("embed", "ffn"), dtype=dt),
        "conv": PSpec(lead + (cfg.conv_width, d), lax_ + ("conv", "ffn"), scale=0.5, dtype=dt),
        "rg_a": PSpec(lead + (d, d), lax_ + ("ffn", None), dtype=dt),
        "b_a": PSpec(lead + (d,), lax_ + (None,), init="zeros", dtype=dt),
        "rg_x": PSpec(lead + (d, d), lax_ + ("ffn", None), dtype=dt),
        "b_x": PSpec(lead + (d,), lax_ + (None,), init="zeros", dtype=dt),
        "lam": PSpec(lead + (d,), lax_ + (None,), init="ones", dtype=jnp.float32),
        "w_out": PSpec(lead + (d, d), lax_ + ("ffn", "embed"), dtype=dt),
    }


def _rwkv_specs(cfg: ArchConfig, stack: tuple[int, ...]) -> dict[str, Any]:
    d, h, k = cfg.d_model, cfg.rwkv_n_heads, cfg.rwkv_head_dim
    dt = _dtype(cfg)
    lead, lax_ = tuple(stack), ("layers",) * len(stack)
    vec = lambda name=None, init="normal", scale=0.02: PSpec(  # noqa: E731
        lead + (d,), lax_ + (None,), init=init, scale=scale, dtype=dt
    )
    return {
        "ln1": vec(init="ones"),
        "mu_r": vec(),
        "mu_k": vec(),
        "mu_v": vec(),
        "mu_w": vec(),
        "mu_g": vec(),
        "w_r": PSpec(lead + (d, d), lax_ + ("embed", "heads"), dtype=dt),
        "w_k": PSpec(lead + (d, d), lax_ + ("embed", "heads"), dtype=dt),
        "w_v": PSpec(lead + (d, d), lax_ + ("embed", "heads"), dtype=dt),
        "w_g": PSpec(lead + (d, d), lax_ + ("embed", "heads"), dtype=dt),
        "w_o": PSpec(lead + (d, d), lax_ + ("heads", "embed"), dtype=dt),
        "w0": PSpec(lead + (d,), lax_ + (None,), init="zeros", dtype=jnp.float32),
        "w_lora_a": PSpec(lead + (d, _RWKV_LORA), lax_ + ("embed", None), dtype=dt),
        "w_lora_b": PSpec(lead + (_RWKV_LORA, d), lax_ + (None, "heads"), dtype=dt),
        "u": PSpec(lead + (h, k), lax_ + ("heads", None), scale=0.5, dtype=jnp.float32),
        "gn": vec(init="ones"),
        "ln2": vec(init="ones"),
        "mu_ck": vec(),
        "mu_cr": vec(),
        "w_ck": PSpec(lead + (d, cfg.d_ff), lax_ + ("embed", "ffn"), dtype=dt),
        "w_cv": PSpec(lead + (cfg.d_ff, d), lax_ + ("ffn", "embed"), dtype=dt),
        "w_cr": PSpec(lead + (d, d), lax_ + ("embed", None), dtype=dt),
    }


def block_specs(cfg: ArchConfig, kind: str, stack: tuple[int, ...] = ()) -> dict[str, Any]:
    if kind in ("attn", "attn_local"):
        specs = _attn_specs(cfg, stack)
        specs.update(_ffn_specs(cfg, stack))
        return specs
    if kind == "xattn":  # decoder block: self-attn + cross-attn + ffn
        specs = {"self": _attn_specs(cfg, stack)}
        specs["cross"] = _attn_specs(cfg, stack)
        specs.update(_ffn_specs(cfg, stack))
        return specs
    if kind == "rglru":
        specs = {"rnn": _rglru_specs(cfg, stack)}
        specs.update(_ffn_specs(cfg, stack))
        return specs
    if kind == "rwkv":
        return _rwkv_specs(cfg, stack)
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def block_cache_specs(
    cfg: ArchConfig, kind: str, batch: int, max_seq: int, stack: tuple[int, ...] = ()
) -> dict[str, Any]:
    d, hd, kv = cfg.d_model, cfg.resolved_head_dim, cfg.n_kv_heads
    dt = _dtype(cfg)
    lead, lax_ = tuple(stack), ("layers",) * len(stack)
    if kind == "attn":
        kvshape = lead + (batch, max_seq, kv, hd)
        kvaxes = lax_ + ("batch", "kv_seq", "kv_heads", "head_dim")
        return {
            "k": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
            "v": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
        }
    if kind == "attn_local":
        w = min(cfg.window or max_seq, max_seq)
        kvshape = lead + (batch, w, kv, hd)
        kvaxes = lax_ + ("batch", None, "kv_heads", "head_dim")
        return {
            "k": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
            "v": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
            "slot_pos": PSpec(
                lead + (batch, w), lax_ + ("batch", None), init="const", const=-1,
                dtype=jnp.int32,
            ),
        }
    if kind == "xattn":
        self_cache = block_cache_specs(cfg, "attn", batch, max_seq, stack)
        # cross K/V over the encoder memory; filled once at prefill
        enc_len = max_seq
        kvshape = lead + (batch, enc_len, kv, hd)
        kvaxes = lax_ + ("batch", "kv_seq", "kv_heads", "head_dim")
        return {
            "self": self_cache,
            "xk": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
            "xv": PSpec(kvshape, kvaxes, init="zeros", dtype=dt),
        }
    if kind == "rglru":
        return {
            "h": PSpec(lead + (batch, d), lax_ + ("batch", "ffn"), init="zeros", dtype=jnp.float32),
            "conv": PSpec(
                lead + (batch, cfg.conv_width - 1, d),
                lax_ + ("batch", None, "ffn"),
                init="zeros",
                dtype=dt,
            ),
        }
    if kind == "rwkv":
        h, k = cfg.rwkv_n_heads, cfg.rwkv_head_dim
        return {
            "wkv": PSpec(
                lead + (batch, h, k, k),
                lax_ + ("batch", "heads", None, None),
                init="zeros",
                dtype=jnp.float32,
            ),
            "shift_tm": PSpec(lead + (batch, d), lax_ + ("batch", None), init="zeros", dtype=dt),
            "shift_cm": PSpec(lead + (batch, d), lax_ + ("batch", None), init="zeros", dtype=dt),
        }
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Attention blocks
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.use_qk_norm:
        q = norm(cfg, q, p["qn"])
        k = norm(cfg, k, p["kn"])
    return q, k, v


def _attn_core_train(cfg, p, h, rope, *, window, causal, mode, cache):
    """Self-attention over a full sequence (train or prefill)."""
    x = norm(cfg, h, p["ln"])
    q, k, v = _project_qkv(cfg, p, x)
    if rope is not None:
        cos, sin = rope
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    att = attention(
        q, k, v, impl=cfg.attn_impl, causal=causal, window=window, chunk=cfg.attn_chunk
    )
    att = constrain(att, "batch", "seq", "heads", None)
    out = jnp.einsum("bsq,qd->bsd", att.reshape(att.shape[0], att.shape[1], -1), p["wo"])
    h = h + out
    new_cache = None
    if mode == "prefill":
        s = k.shape[1]
        if window is None:
            if cache is not None and cache["k"].shape[1] != s:
                ck = cache["k"].at[:, :s].set(k)
                cv = cache["v"].at[:, :s].set(v)
            else:
                ck, cv = k, v
            new_cache = {"k": ck, "v": cv}
        else:
            w = min(window, s) if cache is None else cache["k"].shape[1]
            w_fill = min(s, w)
            positions = jnp.arange(s - w_fill, s)
            slots = positions % w
            ck = jnp.zeros((k.shape[0], w, k.shape[2], k.shape[3]), k.dtype)
            cv = jnp.zeros_like(ck)
            sp = jnp.full((k.shape[0], w), -1, jnp.int32)
            ck = ck.at[:, slots].set(k[:, s - w_fill:])
            cv = cv.at[:, slots].set(v[:, s - w_fill:])
            sp = sp.at[:, slots].set(positions.astype(jnp.int32))
            new_cache = {"k": ck, "v": cv, "slot_pos": sp}
    return h, new_cache


def _attn_core_decode(cfg, p, h, cache, pos, *, window):
    """One-token self-attention against the cache. h: (B,1,D); pos: (B,)."""
    x = norm(cfg, h, p["ln"])
    q, k, v = _project_qkv(cfg, p, x)
    cos, sin = rotary_embedding(pos[:, None], cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    b = q.shape[0]
    if window is None:
        ck = cache["k"].at[jnp.arange(b), pos].set(k[:, 0])
        cv = cache["v"].at[jnp.arange(b), pos].set(v[:, 0])
        valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
        new_cache = {"k": ck, "v": cv}
    else:
        w = cache["k"].shape[1]
        slot = pos % w
        ck = cache["k"].at[jnp.arange(b), slot].set(k[:, 0])
        cv = cache["v"].at[jnp.arange(b), slot].set(v[:, 0])
        sp = cache["slot_pos"].at[jnp.arange(b), slot].set(pos.astype(jnp.int32))
        valid = (sp >= 0) & (sp > (pos[:, None] - window)) & (sp <= pos[:, None])
        new_cache = {"k": ck, "v": cv, "slot_pos": sp}
    att = decode_attention(q, ck, cv, valid)
    out = jnp.einsum("bsq,qd->bsd", att.reshape(b, 1, -1), p["wo"])
    return h + out, new_cache


def _ffn_apply(cfg, p, h):
    x = norm(cfg, h, p["ln2"])
    if cfg.moe is not None:
        y, aux = moe_apply(cfg, p["moe"], x)
    else:
        y, aux = gated_mlp(p["mlp"], x), jnp.zeros((), jnp.float32)
    return h + y, aux


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------

def _causal_conv(p_conv, x, state):
    """Depthwise causal conv, width cw. x: (B,S,D); state: (B,cw-1,D) or None."""
    cw = p_conv.shape[0]
    b, s, d = x.shape
    if state is None:
        state = jnp.zeros((b, cw - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+cw-1, D)
    y = sum(xp[:, j : j + s] * p_conv[j][None, None, :] for j in range(cw))
    new_state = xp[:, s:]  # last cw-1 inputs
    return y, new_state


def _rglru_gates(cfg, p, u):
    """Compute decay a and driven input b for the recurrence (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["rg_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["rg_x"]).astype(jnp.float32) + p["b_x"])
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _rglru_block(cfg, p, h, *, mode, cache):
    rp = p["rnn"]
    x = norm(cfg, h, rp["ln"])
    u = jnp.einsum("bsd,de->bse", x, rp["w_in"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, rp["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(rp["conv"], u, conv_state)
    a, bdrive = _rglru_gates(cfg, rp, u)
    if mode == "decode":
        h0 = cache["h"]
        hseq = a[:, 0] * h0 + bdrive[:, 0]
        new_h = hseq
        hseq = hseq[:, None].astype(x.dtype)
    else:
        h0 = cache["h"] if cache is not None else None
        hseq, new_h = rglru_ops.linear_recurrence(a, bdrive, h0)
        hseq = hseq.astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", hseq * gate, rp["w_out"])
    h = h + y
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"h": new_h.astype(jnp.float32), "conv": new_conv}
    return h, new_cache


# ---------------------------------------------------------------------------
# RWKV-6 block
# ---------------------------------------------------------------------------

def _token_shift(x, state):
    """xprev_t = x_{t-1}; first position takes `state` (or zero)."""
    b, s, d = x.shape
    first = state[:, None] if state is not None else jnp.zeros((b, 1, d), x.dtype)
    if s == 1:
        return first
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _rwkv_block(cfg, p, h, *, mode, cache):
    b, s, d = h.shape
    nh, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    # --- time mix ---
    x = norm(cfg, h, p["ln1"])
    xprev = _token_shift(x, cache["shift_tm"] if cache is not None else None)
    mix = lambda mu: x + (xprev - x) * mu[None, None, :]  # noqa: E731
    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["w_r"]).reshape(b, s, nh, hd)
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["w_k"]).reshape(b, s, nh, hd)
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["w_v"]).reshape(b, s, nh, hd)
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["w_g"])
    w_dyn = jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", mix(p["mu_w"]), p["w_lora_a"])),
        p["w_lora_b"],
    )
    logw = -jnp.exp(jnp.clip(p["w0"][None, None, :] + w_dyn.astype(jnp.float32), -8.0, 8.0))
    w = jnp.exp(logw).reshape(b, s, nh, hd)
    state = cache["wkv"] if cache is not None else None
    impl = "ref" if mode == "decode" else "chunked"
    y, new_state = rwkv_ops.wkv6(r, k, v, w, p["u"], state, impl=impl)
    # per-head group norm, gate, out projection
    y = norm(cfg, y, jnp.ones((hd,), y.dtype)).reshape(b, s, d)
    y = y * p["gn"][None, None, :].astype(y.dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    h = h + jnp.einsum("bse,ed->bsd", y, p["w_o"])
    new_shift_tm = x[:, -1]
    # --- channel mix ---
    x2 = norm(cfg, h, p["ln2"])
    x2prev = _token_shift(x2, cache["shift_cm"] if cache is not None else None)
    mix2 = lambda mu: x2 + (x2prev - x2) * mu[None, None, :]  # noqa: E731
    kc = jnp.einsum("bsd,df->bsf", mix2(p["mu_ck"]), p["w_ck"])
    kc = jnp.square(jax.nn.relu(kc.astype(jnp.float32))).astype(x2.dtype)
    rc = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", mix2(p["mu_cr"]), p["w_cr"]).astype(jnp.float32)
    ).astype(x2.dtype)
    h = h + rc * jnp.einsum("bsf,fd->bsd", kc, p["w_cv"])
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"wkv": new_state, "shift_tm": new_shift_tm, "shift_cm": x2[:, -1]}
    return h, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)
# ---------------------------------------------------------------------------

def _cross_attn(cfg, p, h, enc_out=None, cache=None, pos=None, mode="train"):
    """Cross-attention: queries from h, K/V from encoder memory."""
    b = h.shape[0]
    x = norm(cfg, h, p["ln"])
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(b, x.shape[1], cfg.n_heads, hd)
    if mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
        valid = jnp.ones((b, xk.shape[1]), bool)
        att = decode_attention(q, xk, xv, valid)
        new_kv = None
    else:
        xk = jnp.einsum("bsd,dq->bsq", enc_out, p["wk"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, hd
        )
        xv = jnp.einsum("bsd,dq->bsq", enc_out, p["wv"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, hd
        )
        att = attention(q, xk, xv, impl=cfg.attn_impl, causal=False, chunk=cfg.attn_chunk)
        new_kv = (xk, xv)
    out = jnp.einsum("bsq,qd->bsd", att.reshape(b, att.shape[1], -1), p["wo"])
    return h + out, new_kv


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def block_apply(
    cfg: ArchConfig,
    kind: str,
    p: dict[str, Any],
    h: jax.Array,
    *,
    rope=None,
    mode: str = "train",
    cache: dict[str, Any] | None = None,
    pos: jax.Array | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
) -> tuple[jax.Array, dict[str, Any] | None, jax.Array]:
    """Apply one block. Returns (h, new_cache, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local"):
        window = cfg.window if kind == "attn_local" else None
        if mode == "decode":
            h, new_attn = _attn_core_decode(cfg, p, h, cache, pos, window=window)
        else:
            h, new_attn = _attn_core_train(
                cfg, p, h, rope, window=window, causal=causal, mode=mode, cache=cache
            )
        h, aux = _ffn_apply(cfg, p, h)
        return h, new_attn, aux
    if kind == "xattn":
        if mode == "decode":
            h, new_self = _attn_core_decode(cfg, p["self"], h, cache["self"], pos, window=None)
            h, _ = _cross_attn(cfg, p["cross"], h, cache=cache, mode="decode")
            new_cache = {"self": new_self, "xk": cache["xk"], "xv": cache["xv"]}
        else:
            h, new_self = _attn_core_train(
                cfg, p["self"], h, rope, window=None, causal=True, mode=mode,
                cache=cache["self"] if cache is not None else None,
            )
            h, new_kv = _cross_attn(cfg, p["cross"], h, enc_out=enc_out, mode=mode)
            new_cache = None
            if mode == "prefill":
                new_cache = {"self": new_self, "xk": new_kv[0], "xv": new_kv[1]}
        h, aux = _ffn_apply(cfg, p, h)
        return h, new_cache, aux
    if kind == "rglru":
        h, new_rnn = _rglru_block(cfg, p, h, mode=mode, cache=cache)
        h, aux = _ffn_apply(cfg, p, h)
        return h, new_rnn, aux
    if kind == "rwkv":
        h, new_cache = _rwkv_block(cfg, p, h, mode=mode, cache=cache)
        return h, new_cache, zero
    raise ValueError(f"unknown block kind {kind!r}")
