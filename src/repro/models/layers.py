"""Shared layer library: parameter specs, norms, rotary embeddings, MLPs.

Parameter handling convention
-----------------------------
Model code describes parameters with :class:`PSpec` trees (shape + logical axes
+ initializer).  ``materialize`` turns a spec tree into real arrays;
``abstract`` turns it into ``jax.ShapeDtypeStruct``s (used by the dry-run so
trillion-parameter configs never allocate); ``axes_tree`` extracts the logical
axes used by ``dist.sharding`` to build NamedShardings.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import Axes


__all__ = [
    "PSpec",
    "materialize",
    "abstract",
    "axes_tree",
    "is_pspec",
    "rms_norm",
    "rotary_embedding",
    "apply_rotary",
    "gated_mlp_specs",
    "gated_mlp",
    "count_params",
]


@dataclass(frozen=True)
class PSpec:
    """Declarative parameter spec: shape, logical axes, init, dtype."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | const
    scale: float | None = None  # stddev for normal; default fan-in
    dtype: Any = jnp.bfloat16
    const: float = 0.0  # fill value for init == "const"

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"PSpec shape {self.shape} vs axes {self.axes}")


def is_pspec(x: Any) -> bool:
    return isinstance(x, PSpec)


def _fan_in(shape: Sequence[int]) -> int:
    # for stacked layer params the leading "layers" dim is not a fan-in dim;
    # use the second-to-last dim as fan-in (matmul convention: (..., in, out)).
    if len(shape) >= 2:
        return shape[-2]
    return max(shape[-1], 1)


def materialize(specs, key: jax.Array):
    """Instantiate a PSpec tree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_pspec)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "const":
            arr = jnp.full(spec.shape, spec.const, spec.dtype)
        elif spec.init == "normal":
            scale = spec.scale if spec.scale is not None else _fan_in(spec.shape) ** -0.5
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(spec.dtype)
        else:  # pragma: no cover
            raise ValueError(f"unknown init {spec.init!r}")
        arrays.append(arr)
    return jax.tree.unflatten(treedef, arrays)


def abstract(specs):
    """PSpec tree -> ShapeDtypeStruct tree (no allocation; dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_pspec
    )


def axes_tree(specs):
    """PSpec tree -> logical-axes tree (same structure, Axes leaves)."""
    return jax.tree.map(lambda s: Axes(s.axes), specs, is_leaf=is_pspec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_pspec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rotary_embedding(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` (any shape) -> (*pos.shape, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Apply rotary embedding.

    x: (..., S, H, head_dim); cos/sin: (..., S, head_dim//2) broadcastable —
    typically (B, S, half) or (S, half).
    """
    dtype = x.dtype
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    # insert head axis for broadcast: cos (..., S, half) -> (..., S, 1, half)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def gated_mlp_specs(d_model: int, d_ff: int, dtype, stack: tuple[int, ...] = ()) -> dict[str, PSpec]:
    lead = tuple(stack)
    lax = ("layers",) * len(stack)
    return {
        "wi": PSpec(lead + (d_model, d_ff), lax + ("embed", "ffn"), dtype=dtype),
        "wg": PSpec(lead + (d_model, d_ff), lax + ("embed", "ffn"), dtype=dtype),
        "wo": PSpec(lead + (d_ff, d_model), lax + ("ffn", "embed"), dtype=dtype),
    }


def gated_mlp(p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["wi"])
    gate = jnp.einsum("bsd,df->bsf", x, p["wg"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("bsf,fd->bsd", hidden, p["wo"])
