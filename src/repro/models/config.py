"""Architecture and input-shape configuration.

Each assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published shape) and ``SMOKE_CONFIG`` (a reduced config of
the same family for CPU tests).  ``ShapeConfig`` encodes the assigned input
shapes; ``train_*`` shapes lower ``train_step``, ``prefill_*`` lower the prefill
step, and ``decode_*``/``long_*`` lower ``serve_step`` (one new token against a
KV cache of ``seq_len``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = ["MoESettings", "ArchConfig", "ShapeConfig", "SHAPES"]


@dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_expert: int
    #: capacity factor for dropping-style dispatch (GShard); tokens above
    #: capacity are dropped to keep dispatch tensors static.
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    rope_theta: float = 500000.0
    use_qk_norm: bool = False
    tied_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: MoESettings | None = None
    #: layer-type cycle; dense = ("attn",), hybrid e.g. ("rglru","attn_local","attn_local")
    block_pattern: tuple[str, ...] = ("attn",)
    #: sliding window for attn_local blocks
    window: int | None = None
    #: encoder layers (enc-dec archs; n_layers is then the decoder depth)
    n_enc_layers: int = 0
    #: [vlm]: number of stub patch embeddings prepended to the text sequence
    n_vision_patches: int = 0
    #: [audio]: source sequence is precomputed frame embeddings (stub frontend)
    audio_frontend: bool = False
    #: rwkv6 head size (state is head_dim x head_dim per head)
    rwkv_head_dim: int = 64
    #: conv width for RG-LRU blocks
    conv_width: int = 4
    rglru_c: float = 8.0
    dtype: str = "bfloat16"
    #: sharding preset: "tp" | "tp+fsdp" ; see dist/sharding.py
    sharding: str = "tp"
    #: remat policy for the layer scan: "none" | "dots" | "full"
    remat: str = "dots"
    #: attention implementation: "naive" | "chunked" (default) | "pallas"
    attn_impl: str = "chunked"
    attn_chunk: int = 512
    #: rmsnorm implementation: "ref" (unfused, default) | "fused" (Pallas
    #: kernel; interpret-mode on CPU via default_interpret)
    norm_impl: str = "ref"
    #: pad vocab up to a multiple of this for sharding (logits masked to true vocab)
    vocab_pad_to: int = 256
    #: §Perf knobs (EXPERIMENTS.md): pre-reshard embedding/lm_head before the
    #: token gather (fixes FSDP involuntary remat)
    embed_gather_constraint: bool = False
    #: MoE dispatch activation constraints: "embed" (baseline; constrains the
    #: hidden dim, conflicts with FSDP) | "tokens" (batch+expert only)
    moe_dispatch_mode: str = "embed"
    #: chunked cross-entropy: compute logits+CE in seq chunks of this size
    loss_chunk: int = 0
    #: source/published reference for the config
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        pad = self.vocab_pad_to
        return ((self.vocab_size + pad - 1) // pad) * pad

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        """Expanded per-layer block kinds of length n_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def n_params(self, active_only: bool = False) -> int:
        """Approximate parameter count (used for 6·N·D model-FLOPs)."""
        d, hd = self.d_model, self.resolved_head_dim
        per_layer = 0
        kinds = self.layer_kinds()
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        for kind in kinds:
            if kind in ("attn", "attn_local"):
                per_layer += attn
            elif kind == "rglru":
                # in/out projections + gates (diagonal recurrence)
                per_layer += 2 * d * d + 2 * d * d // 1 + 3 * d
            elif kind == "rwkv":
                h = self.rwkv_n_heads
                per_layer += 4 * d * d + d * self.rwkv_head_dim  # r,k,v,o + decay lora (approx)
            if self.moe is not None and kind != "rwkv":
                experts = self.moe.top_k if active_only else self.moe.n_experts
                per_layer += experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
            elif kind == "rwkv":
                per_layer += 2 * d * self.d_ff + d * d  # channel mix (k,v,r)
            else:
                per_layer += 3 * d * self.d_ff  # gated mlp
        total = per_layer * self.n_layers
        # encoder stack (same block shape, attn + mlp)
        total += self.n_enc_layers * (attn + 3 * d * self.d_ff)
        total += self.padded_vocab * d * (1 if self.tied_embeddings else 2)
        return total

    def replace(self, **kwargs) -> ArchConfig:
        return dataclasses.replace(self, **kwargs)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a shape cell runs for an arch (DESIGN.md §4 records the skips)."""
    if shape.name == "long_500k":
        kinds = set(cfg.layer_kinds())
        sub_quadratic = kinds <= {"rglru", "attn_local", "rwkv"} and (
            "rglru" in kinds or "rwkv" in kinds
        )
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k decode is not sub-quadratic"
    return True, ""
