"""Unified model API over all assigned families.

Layer stacks ``lax.scan`` over *pattern periods*: the block-pattern (e.g.
``("rglru","attn_local","attn_local")`` for the hybrid arch) forms one scanned
unit, so heterogeneous stacks still compile to a single rolled loop (small HLO,
fast compiles, natural remat boundary).  Layers that do not fill a whole period
run unrolled as the "tail".

Public surface:
    model_specs(cfg)                 -> PSpec tree (params, never allocated)
    init_params(cfg, key)            -> concrete params
    abstract_params(cfg)             -> ShapeDtypeStruct tree (dry-run)
    param_axes(cfg)                  -> logical-axes tree
    param_counts(cfg)                -> (total, active) parameter counts
    cache_specs(cfg, batch, max_seq) -> PSpec tree (decode cache)
    loss_fn(cfg, params, batch)      -> (loss, metrics)
    prefill(cfg, params, batch, cache) -> (cache, last_logits)
    decode_step(cfg, params, cache, tokens) -> (cache, logits)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from .blocks import block_apply, block_cache_specs, block_specs
from .config import ArchConfig
from .layers import PSpec, abstract, axes_tree, is_pspec, materialize, rms_norm, rotary_embedding

__all__ = [
    "model_specs",
    "init_params",
    "abstract_params",
    "param_axes",
    "param_counts",
    "cache_specs",
    "cache_axes",
    "init_cache",
    "abstract_cache",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def decoder_pattern(cfg: ArchConfig) -> tuple[str, ...]:
    """Block pattern of the decoder stack (enc-dec decoders use xattn blocks)."""
    return ("xattn",) if cfg.family == "encdec" else cfg.block_pattern


def _split_stack(cfg: ArchConfig) -> tuple[int, tuple[str, ...], tuple[str, ...]]:
    """(n_scan_units, pattern, tail_kinds) for the decoder stack."""
    pattern = decoder_pattern(cfg)
    p = len(pattern)
    n_scan = cfg.n_layers // p
    tail = tuple(pattern[i % p] for i in range(n_scan * p, cfg.n_layers))
    return n_scan, pattern, tail


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ArchConfig) -> dict[str, Any]:
    dt = _dtype(cfg)
    d, vp = cfg.d_model, cfg.padded_vocab
    n_scan, pattern, tail = _split_stack(cfg)
    specs: dict[str, Any] = {
        "embed": {"tokens": PSpec((vp, d), ("vocab", "embed"), scale=0.02, dtype=dt)},
        "final_norm": PSpec((d,), (None,), init="ones", dtype=dt),
        "scan": tuple(block_specs(cfg, k, (n_scan,)) for k in pattern) if n_scan else None,
        "tail": tuple(block_specs(cfg, k) for k in tail),
    }
    if not cfg.tied_embeddings:
        specs["lm_head"] = PSpec((d, vp), ("embed", "vocab"), dtype=dt)
    if cfg.family == "encdec":
        n_enc = cfg.n_enc_layers
        specs["enc_scan"] = (block_specs(cfg, "attn", (n_enc,)),) if n_enc else None
        specs["enc_final_norm"] = PSpec((d,), (None,), init="ones", dtype=dt)
    return specs


def init_params(cfg: ArchConfig, key: jax.Array):
    return materialize(model_specs(cfg), key)


def abstract_params(cfg: ArchConfig):
    return abstract(model_specs(cfg))


def param_axes(cfg: ArchConfig):
    return axes_tree(model_specs(cfg))


def param_counts(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active) — active scales expert weights by top_k / n_experts and
    excludes embedding/lm_head (6·N·D convention counts matmul params)."""
    import numpy as np

    specs = model_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_pspec)[0]
    total = 0
    active = 0
    for path, spec in flat:
        n = int(np.prod(spec.shape))
        total += n
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if "embed" in keys or "lm_head" in keys:
            continue
        if cfg.moe is not None and "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
            active += int(n * cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return total, active


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> dict[str, Any]:
    n_scan, pattern, tail = _split_stack(cfg)
    return {
        "pos": PSpec((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        "scan": tuple(
            block_cache_specs(cfg, k, batch, max_seq, (n_scan,)) for k in pattern
        )
        if n_scan
        else None,
        "tail": tuple(block_cache_specs(cfg, k, batch, max_seq) for k in tail),
    }


def cache_axes(cfg: ArchConfig, batch: int, max_seq: int):
    return axes_tree(cache_specs(cfg, batch, max_seq))


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return materialize(cache_specs(cfg, batch, max_seq), jax.random.PRNGKey(0))


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return abstract(cache_specs(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _unit_apply(cfg, kinds, unit_p, h, *, rope, mode, unit_cache, pos, enc_out, causal):
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(kinds):
        c = unit_cache[i] if unit_cache is not None else None
        h, nc, a = block_apply(
            cfg, kind, unit_p[i], h, rope=rope, mode=mode, cache=c, pos=pos,
            enc_out=enc_out, causal=causal,
        )
        new_caches.append(nc)
        aux = aux + a
    return h, tuple(new_caches), aux


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing


def _apply_stack(
    cfg,
    params,
    h,
    *,
    kinds_pattern,
    scan_key,
    tail_key,
    rope,
    mode,
    caches=None,
    pos=None,
    enc_out=None,
    causal=True,
):
    """Run the scanned units then the tail. Returns (h, new_caches, aux)."""
    aux = jnp.zeros((), jnp.float32)
    p_scan = params.get(scan_key)
    c_scan = caches.get(scan_key) if caches is not None else None
    new_scan = None
    if p_scan is not None:
        def body(carry, xs):
            h, aux = carry
            unit_p, unit_c = xs
            h, new_c, a = _unit_apply(
                cfg, kinds_pattern, unit_p, h, rope=rope, mode=mode,
                unit_cache=unit_c, pos=pos, enc_out=enc_out, causal=causal,
            )
            return (h, aux + a), new_c

        if mode == "train":
            body = _remat_wrap(cfg, body)
        (h, aux), new_scan = jax.lax.scan(body, (h, aux), (p_scan, c_scan))
    new_tail = []
    tail_p = params.get(tail_key, ())
    for i, bp in enumerate(tail_p):
        kind = kinds_pattern[i % len(kinds_pattern)]
        c = caches[tail_key][i] if caches is not None else None
        h, nc, a = block_apply(
            cfg, kind, bp, h, rope=rope, mode=mode, cache=c, pos=pos,
            enc_out=enc_out, causal=causal,
        )
        new_tail.append(nc)
        aux = aux + a
    return h, {"scan": new_scan, "tail": tuple(new_tail)}, aux


def _embed_tokens(cfg, params, tokens):
    table = params["embed"]["tokens"]
    if cfg.embed_gather_constraint:
        # pre-reshard: keep vocab sharded, gather the (FSDP-sharded) embed dim
        # first — avoids SPMD "involuntary full rematerialization" of the
        # token gather (EXPERIMENTS.md §Perf H3)
        table = constrain(table, "vocab", None)
    x = table[tokens]
    return constrain(x, "batch", "seq", None)


def _build_inputs(cfg, params, batch):
    """Token/frontend embedding for train/prefill. Returns (h, n_prefix)."""
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(_dtype(cfg))
        text = _embed_tokens(cfg, params, batch["tokens"])
        return jnp.concatenate([patches, text], axis=1), patches.shape[1]
    return _embed_tokens(cfg, params, batch["tokens"]), 0


def _logits(cfg, params, h):
    if cfg.tied_embeddings:
        table = params["embed"]["tokens"]
        if cfg.embed_gather_constraint:
            table = constrain(table, "vocab", None)
        logits = jnp.einsum("bsd,vd->bsv", h, table)
    else:
        head = params["lm_head"]
        if cfg.embed_gather_constraint:
            head = constrain(head, None, "vocab")
        logits = jnp.einsum("bsd,dv->bsv", h, head)
    return constrain(logits, "batch", "seq", "vocab")


def _encode(cfg, params, batch, mode="train"):
    """Encoder stack over precomputed source-frame embeddings (stub frontend)."""
    src = batch["src_frames"].astype(_dtype(cfg))
    s = src.shape[1]
    rope = rotary_embedding(jnp.arange(s), cfg.resolved_head_dim, cfg.rope_theta)
    enc_mode = "train" if mode != "decode" else "train"
    h, _, _ = _apply_stack(
        cfg, params, src, kinds_pattern=("attn",), scan_key="enc_scan",
        tail_key="_enc_tail_none", rope=rope, mode=enc_mode, causal=False,
    )
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train / prefill / decode entry points
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params, batch) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    pattern = decoder_pattern(cfg)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch)
    h, n_prefix = _build_inputs(cfg, params, batch)
    s = h.shape[1]
    rope = rotary_embedding(jnp.arange(s), cfg.resolved_head_dim, cfg.rope_theta)
    h, _, aux = _apply_stack(
        cfg, params, h, kinds_pattern=pattern, scan_key="scan", tail_key="tail",
        rope=rope, mode="train", enc_out=enc_out,
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, h)
    if n_prefix:
        st = batch["tokens"].shape[1]
        logits = logits[:, n_prefix - 1 : n_prefix - 1 + st]
    return logits, aux


def _ce_terms(cfg, logits, targets, z_coef):
    """(Σ ce, Σ z, Σ valid) over one logits block; f32, padded vocab masked."""
    lf = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        lf = jnp.where(vmask[None, None, :], lf, -1e30)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
    valid = (targets >= 0).astype(jnp.float32)
    return (
        jnp.sum((lse - gold) * valid),
        z_coef * jnp.sum(jnp.square(lse) * valid),
        jnp.sum(valid),
    )


def _hidden_for_loss(cfg: ArchConfig, params, batch):
    """Forward up to the final norm; returns (h_text, aux). h_text aligns with
    ``targets`` (vlm prefixes already rebased, like forward's logit slice)."""
    pattern = decoder_pattern(cfg)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch)
    h, n_prefix = _build_inputs(cfg, params, batch)
    s = h.shape[1]
    rope = rotary_embedding(jnp.arange(s), cfg.resolved_head_dim, cfg.rope_theta)
    h, _, aux = _apply_stack(
        cfg, params, h, kinds_pattern=pattern, scan_key="scan", tail_key="tail",
        rope=rope, mode="train", enc_out=enc_out,
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        st = batch["tokens"].shape[1]
        h = h[:, n_prefix - 1 : n_prefix - 1 + st]
    return h, aux


def loss_fn(cfg: ArchConfig, params, batch, aux_coef: float = 0.01, z_coef: float = 1e-4):
    targets = batch["targets"]
    chunk = cfg.loss_chunk
    if chunk and targets.shape[1] % chunk == 0 and targets.shape[1] > chunk:
        # §Perf H3: chunked cross-entropy — the (B,S,V) logits tensor never
        # materializes; each seq chunk computes logits+CE under remat.
        h, aux = _hidden_for_loss(cfg, params, batch)
        b, s, d = h.shape
        n = s // chunk
        hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
        tc = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)

        @jax.checkpoint
        def body(carry, xs):
            hb, tb = xs
            logits = _logits(cfg, params, hb)
            ce_s, z_s, v_s = _ce_terms(cfg, logits, tb, z_coef)
            c0, c1, c2 = carry
            return (c0 + ce_s, c1 + z_s, c2 + v_s), None

        (ce_sum, z_sum, n_valid), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hc, tc)
        )
        n_valid = jnp.maximum(n_valid, 1.0)
        ce = ce_sum / n_valid
        z_loss = z_sum / n_valid
    else:
        logits, aux = forward(cfg, params, batch)
        ce_sum, z_sum, n_valid = _ce_terms(cfg, logits, targets, z_coef)
        n_valid = jnp.maximum(n_valid, 1.0)
        ce = ce_sum / n_valid
        z_loss = z_sum / n_valid
    loss = ce + z_loss + aux_coef * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux, "z_loss": z_loss, "tokens": n_valid}
    return loss, metrics


def prefill(cfg: ArchConfig, params, batch, cache):
    """Fill the decode cache from a full prompt; returns (cache, last_logits)."""
    pattern = decoder_pattern(cfg)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch, mode="prefill")
    h, n_prefix = _build_inputs(cfg, params, batch)
    s = h.shape[1]
    rope = rotary_embedding(jnp.arange(s), cfg.resolved_head_dim, cfg.rope_theta)
    h, new_caches, _ = _apply_stack(
        cfg, params, h, kinds_pattern=pattern, scan_key="scan", tail_key="tail",
        rope=rope, mode="prefill", caches=cache, enc_out=enc_out,
    )
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, h)
    new_cache = {
        "pos": jnp.full((h.shape[0],), s, jnp.int32),
        "scan": new_caches["scan"],
        "tail": new_caches["tail"],
    }
    return new_cache, logits[:, 0]


def decode_step(cfg: ArchConfig, params, cache, tokens):
    """One decode step. tokens: (B, 1). Returns (cache, logits (B, vocab))."""
    pattern = decoder_pattern(cfg)
    pos = cache["pos"]
    h = _embed_tokens(cfg, params, tokens)
    h, new_caches, _ = _apply_stack(
        cfg, params, h, kinds_pattern=pattern, scan_key="scan", tail_key="tail",
        rope=None, mode="decode", caches=cache, pos=pos,
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, h)
    new_cache = {"pos": pos + 1, "scan": new_caches["scan"], "tail": new_caches["tail"]}
    return new_cache, logits[:, 0]
