"""Deterministic synthetic LM data.

Modes:
  * ``random`` — uniform tokens (throughput/dry-run benchmarking).
  * ``copy``   — first half random, second half repeats the first half with
    next-token targets; a learnable induction task (examples/train_llm.py).
  * ``skewed`` — Zipf-distributed tokens; the unigram statistics are learnable
    within tens of steps (fast integration tests).

Batches are a pure function of (seed, step), so any host can regenerate any
step — resuming from a checkpointed step id reproduces the exact stream
(elastic restarts included).  Modality stubs (frames/patches) are derived from
the same counter-based PRNG.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..models.config import ArchConfig

__all__ = ["SyntheticConfig", "SyntheticLM"]


@dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    mode: str = "copy"  # "copy" | "random" | "skewed"
    seed: int = 0


class SyntheticLM:
    def __init__(self, cfg: SyntheticConfig, arch: ArchConfig | None = None) -> None:
        self.cfg = cfg
        self.arch = arch

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence([self.cfg.seed, step]))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng(step)
        b, s, v = c.global_batch, c.seq_len, c.vocab_size
        if c.mode == "random":
            tokens = rng.integers(0, v, (b, s), dtype=np.int32)
        elif c.mode == "skewed":
            # Zipf-like unigram distribution: learnable within tens of steps
            # (the model only has to match token frequencies)
            probs = 1.0 / (np.arange(v) + 2.0)
            probs /= probs.sum()
            tokens = rng.choice(v, size=(b, s), p=probs).astype(np.int32)
        else:  # copy task
            half = s // 2
            prefix = rng.integers(0, v, (b, half), dtype=np.int32)
            tokens = np.concatenate([prefix, prefix[:, : s - half]], axis=1)
        targets = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1
        )
        if c.mode == "copy":
            # only score the (learnable) copied half
            half = s // 2
            masked = targets.copy()
            masked[:, : half - 1] = -1
            targets = masked
        batch = {"tokens": tokens, "targets": targets}
        if self.arch is not None:
            d = self.arch.d_model
            if self.arch.family == "vlm":
                p = self.arch.n_vision_patches
                batch["patch_embeds"] = rng.standard_normal((b, p, d)).astype(np.float32) * 0.02
            if self.arch.family == "encdec":
                batch["src_frames"] = rng.standard_normal((b, s, d)).astype(np.float32) * 0.02
        return batch
