from .synthetic import SyntheticConfig, SyntheticLM
from .loader import DataLoader

__all__ = ["SyntheticConfig", "SyntheticLM", "DataLoader"]
