
from .loader import DataLoader
from .synthetic import SyntheticConfig, SyntheticLM


__all__ = ["SyntheticConfig", "SyntheticLM", "DataLoader"]
