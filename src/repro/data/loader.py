"""Checkpointable data loader with background prefetch.

State is a single integer step counter (the synthetic source is a pure function
of the step), checkpointed alongside the model so restarts resume the stream
exactly.  A daemon thread prefetches ``prefetch`` batches ahead; fetch time is
visible to the timing infrastructure through the PRESTEP bin timer.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from .synthetic import SyntheticLM

__all__ = ["DataLoader"]


class DataLoader:
    def __init__(self, source: SyntheticLM, start_step: int = 0, prefetch: int = 2) -> None:
        self.source = source
        self._step = int(start_step)
        self._prefetch = int(prefetch)
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if prefetch > 0:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> dict[str, np.ndarray]:
        if self._thread is None:
            batch = self.source.batch_at(self._step)
            self._step += 1
            return batch
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict[str, int]:
        return {"step": self._step}

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            # drain so the worker can observe the stop flag
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)

    @classmethod
    def restore(cls, source: SyntheticLM, state: dict[str, int], prefetch: int = 2):
        return cls(source, start_step=int(state["step"]), prefetch=prefetch)
