"""Pure-jnp oracle for the RWKV-6 WKV recurrence (sequential scan over time).

Per head, with state ``S ∈ R^{K×V}``, data-dependent per-channel decay
``w_t ∈ (0,1)^K`` and bonus ``u ∈ R^K`` (Finch, arXiv:2404.05892):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Shapes: r/k/w (B,S,H,K); v (B,S,H,V); u (H,K); state (B,H,K,V).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = ["wkv6_ref"]


def wkv6_ref(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,K)/(B,H,V)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + uf[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = (
        jnp.moveaxis(rf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(wf, 1, 0),
    )
    final, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(r.dtype)  # (B,S,H,V)
    return y, final
