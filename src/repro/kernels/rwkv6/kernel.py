"""RWKV-6 WKV Pallas TPU kernel.

TPU adaptation (DESIGN.md §6): GPU RWKV kernels keep the per-head (K,V) state
in registers/shared memory of one CTA and scan tokens sequentially.  On TPU we
keep the state in **VMEM scratch** that persists across the sequential chunk
dimension of the grid: grid = (B·H, n_chunks) with semantics
("parallel", "arbitrary"); each step streams a (chunk, K) tile of r/k/w and a
(chunk, V) tile of v from HBM and runs the token recurrence with VMEM-resident
state.  The recurrence itself is vector-unit work (elementwise + small outer
products); the op is HBM-bandwidth-bound, which is exactly why streaming
chunks with a resident state is the right TPU shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret, tpu_compiler_params

__all__ = ["wkv6_pallas"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_scr, *, chunk, n_chunks):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, K)

    def step(t, carry):
        S, y = carry  # S: (K, V); y: (C, V)
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)  # (1, K)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)  # (1, V)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = k_t.T * v_t  # (K, V) outer product
        y_t = jnp.dot(r_t, S + u.T * kv, preferred_element_type=jnp.float32)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t, t, 0)
        S = w_t.T * S + kv
        return S, y

    S, y = jax.lax.fori_loop(
        0, chunk, step, (state_scr[...], jnp.zeros_like(y_ref[0], jnp.float32))
    )
    state_scr[...] = S
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        sout_ref[0] = state_scr[...]


def wkv6_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
    chunk: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Shapes as ops.wkv6: r/k/w (B,S,H,K); v (B,S,H,V); u (H,K); state (B,H,K,V)."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    interpret = default_interpret(interpret)
    if s % chunk != 0:
        chunk = s  # single block
    n_chunks = s // chunk
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    # fold (B,H) -> one grid axis; layout (BH, S, K)
    def fold(x, d):
        return jnp.swapaxes(x, 1, 2).reshape(b * h, s, d)

    rf, kf, wf = fold(r, dk), fold(k, dk), fold(w, dk)
    vf = fold(v, dv)
    uf = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, 1, dk)
    s0 = state.reshape(b * h, dk, dv)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, dk), lambda bh, ci: (bh, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, dv), r.dtype),
            jax.ShapeDtypeStruct((b * h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary"), interpret),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    y = jnp.swapaxes(y.reshape(b, h, s, dv), 1, 2)
    return y, s_out.reshape(b, h, dk, dv)
