"""WKV-6 ops: chunked-parallel form (default) and the Pallas TPU kernel.

Chunked derivation (stable: every exponent is ≤ 0 inside a chunk):
with in-chunk inclusive log-decay ``L_t = Σ_{j≤t} log w_j`` and
``L⁻_t = L_t − log w_t`` (exclusive),

    y_t  = (r_t ⊙ e^{L⁻_t}) · S_in                     (inter-chunk)
         + Σ_{m<t} [Σ_i r_{t,i} k_{m,i} e^{L⁻_{t,i} − L_{m,i}}] v_m
         + (r_t ⊙ u) · k_t  v_t                        (diagonal bonus)
    S_out = diag(e^{L_{C−1}}) S_in + Σ_m (e^{L_{C−1} − L_m} ⊙ k_m) v_mᵀ

All pairwise exponents have m ≤ t so they are sums of negative log-decays —
no overflow; underflow saturates to 0 which is exact in the limit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import wkv6_ref

__all__ = ["wkv6", "wkv6_chunked"]

_NEG = -1e30


def _chunk_body(u: jax.Array, S: jax.Array, inputs, chunk: int):
    rf, kf, vf, logw = inputs  # (B,C,H,K) / (B,C,H,V)
    # in-chunk cumulative log decays
    l_incl = jnp.cumsum(logw, axis=1)  # (B,C,H,K)
    l_excl = l_incl - logw
    # inter-chunk: (r ⊙ e^{L⁻}) @ S_in
    r_dec = rf * jnp.exp(l_excl)
    y_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, S)
    # intra-chunk strict-lower scores with pairwise decay
    expo = l_excl[:, :, None] - l_incl[:, None, :]  # (B, C_t, C_m, H, K)
    c = chunk
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
    expo = jnp.where(mask, jnp.minimum(expo, 0.0), _NEG)
    scores = jnp.einsum("bthk,bmhk,btmhk->btmh", rf, kf, jnp.exp(expo))
    diag = jnp.einsum("bthk,hk,bthk->bth", rf, u, kf)
    y_intra = jnp.einsum("btmh,bmhv->bthv", scores, vf) + diag[..., None] * vf
    # state update
    l_last = l_incl[:, -1]  # (B,H,K)
    k_dec = kf * jnp.exp(l_last[:, None] - l_incl)
    S_new = jnp.exp(l_last)[..., None] * S + jnp.einsum("bmhk,bmhv->bhkv", k_dec, vf)
    return S_new, y_inter + y_intra


def wkv6_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if s % chunk != 0 or s <= chunk:
        return wkv6_ref(r, k, v, w, u, state)
    n = s // chunk
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)
    rf = r.astype(jnp.float32).reshape(b, n, chunk, h, dk)
    kf = k.astype(jnp.float32).reshape(b, n, chunk, h, dk)
    vf = v.astype(jnp.float32).reshape(b, n, chunk, h, dv)
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38)).reshape(b, n, chunk, h, dk)

    body = functools.partial(_chunk_body, u.astype(jnp.float32))

    def scan_fn(S, xs):
        return body(S, xs, chunk)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, logw))
    final, ys = jax.lax.scan(scan_fn, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dv).astype(r.dtype)
    return y, final


def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
    impl: str = "chunked",
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """WKV-6 with implementation dispatch ("ref" | "chunked" | "pallas")."""
    if impl == "ref":
        return wkv6_ref(r, k, v, w, u, state)
    if impl == "chunked":
        return wkv6_chunked(r, k, v, w, u, state, chunk=chunk)
    if impl == "pallas":
        from .kernel import wkv6_pallas

        return wkv6_pallas(r, k, v, w, u, state)
    raise ValueError(f"unknown wkv6 impl {impl!r}")
