"""jit'd wrapper for the flash attention kernel, with a custom VJP.

Public entry ``flash_attention(q, k, v, causal=..., window=...)`` takes the
model layout (B, S, H, hd) / (B, T, KV, hd), transposes to kernel layout,
runs the Pallas forward, and differentiates through the dq/dkv Pallas kernels.
Falls back to the chunked pure-JAX implementation when shapes do not tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as K
from ...models.attention import chunked_attention
from ..common import default_interpret


__all__ = ["flash_attention"]


def _tiles(s: int, block: int) -> bool:
    return s % block == 0 and s >= block


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash(q, k, v, causal, window, block_q, block_k, interpret):
    out, _ = K.flash_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return out


def _flash_fwd_rule(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = K.flash_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    kv = k.shape[1]
    group = q.shape[1] // kv
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    dq = K.flash_bwd_dq(
        q, k, v, do, lse, delta, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    dk_h, dv_h = K.flash_bwd_dkv(
        q, k, v, do, lse, delta, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    # reduce per-q-head grads onto the KV heads (GQA)
    b, h, t, hd = dk_h.shape
    dk = jnp.sum(dk_h.reshape(b, kv, group, t, hd), axis=2).astype(k.dtype)
    dv = jnp.sum(dv_h.reshape(b, kv, group, t, hd), axis=2).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Model layout in/out: q (B,S,H,hd), k/v (B,T,KV,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    interpret = default_interpret(interpret)
    if not (_tiles(s, block_q) and _tiles(t, block_k)):
        return chunked_attention(q, k, v, causal=causal, window=window)
    qt = jnp.swapaxes(q, 1, 2)  # (B,H,S,hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _flash(qt, kt, vt, causal, window, block_q, block_k, interpret)
    return jnp.swapaxes(out, 1, 2)
