"""Flash attention TPU kernels (Pallas): forward + backward (dq, dkv).

TPU adaptation (DESIGN.md §6): the online-softmax accumulators (m, l, acc)
live in VMEM scratch that persists across the *sequential* last grid dimension
(``arbitrary`` semantics) — the TPU analogue of FlashAttention's SRAM-resident
per-CTA accumulators.  Block shapes are (block_q|k, head_dim) with
head_dim ≥ 128-multiples feeding the MXU; masks are built from
``broadcasted_iota`` (TPU requires ≥2D iota).

Layout: q (B, H, S, hd); k/v (B, KV, T, hd) — the ops wrapper transposes from
the model's (B, S, H, hd).  GQA is handled by indexing the KV head as
``h // group`` in the BlockSpec index maps.  Causal and sliding-window masks
are supported; fully-masked K blocks are skipped with ``pl.when``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params

__all__ = ["flash_fwd", "flash_bwd_dq", "flash_bwd_dkv"]

_NEG_INF = -2.0e38


def _mask(bias_shape, q_start, k_start, causal: bool, window: int | None):
    """Additive mask for a (block_q, block_k) tile, from absolute offsets."""
    bq, bk = bias_shape
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, _NEG_INF)


def _block_needed(iq, ik, block_q, block_k, causal, window):
    """Whether tile (iq, ik) intersects the mask support (traced predicate)."""
    need = jnp.bool_(True)
    if causal:
        need &= ik * block_k <= iq * block_q + block_q - 1
    if window is not None:
        need &= (ik + 1) * block_k - 1 > iq * block_q - window
    return need


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, block_q, block_k, n_k, causal, window):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_block_needed(iq, ik, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s += _mask((block_q, block_k), iq * block_q, ik * block_k, causal, window)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def flash_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    interpret: bool,
):
    """q: (B,H,S,hd); k/v: (B,KV,T,hd). Returns (out (B,H,S,hd), lse (B,H,S))."""
    b, h, s, hd = q.shape
    kv, t = k.shape[1], k.shape[2]
    group = h // kv
    scale = hd ** -0.5
    n_q, n_k = s // block_q, t // block_k
    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary"), interpret
        ),
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward: dq (grid over q blocks, scan k blocks)
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
               *, scale, block_q, block_k, n_k, causal, window):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(_block_needed(iq, ik, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        kb = k_ref[0, 0].astype(jnp.float32)
        vb = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jnp.dot(q, kb.T, preferred_element_type=jnp.float32) * scale
        s += _mask((block_q, block_k), iq * block_q, ik * block_k, causal, window)
        p = jnp.exp(s - lse)
        dp = jnp.dot(do, vb.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dq_scr[...] += jnp.dot(ds, kb, preferred_element_type=jnp.float32) * scale

    @pl.when(ik == n_k - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_bwd_dq(q, k, v, do, lse, delta, *, causal, window, block_q, block_k, interpret):
    b, h, s, hd = q.shape
    kv, t = k.shape[1], k.shape[2]
    group = h // kv
    n_q, n_k = s // block_q, t // block_k
    kernel = functools.partial(
        _dq_kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary"), interpret
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# Backward: dk/dv (grid over k blocks, scan q blocks; per q-head, summed to
# KV heads by the ops wrapper)
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                dk_scr, dv_scr, *, scale, block_q, block_k, n_q, causal, window):
    ik, iq = pl.program_id(2), pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_block_needed(iq, ik, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        kb = k_ref[0, 0].astype(jnp.float32)
        vb = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jnp.dot(q, kb.T, preferred_element_type=jnp.float32) * scale
        s += _mask((block_q, block_k), iq * block_q, ik * block_k, causal, window)
        p = jnp.exp(s - lse)  # (bq, bk)
        dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, vb.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_scr[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale

    @pl.when(iq == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_dkv(q, k, v, do, lse, delta, *, causal, window, block_q, block_k, interpret):
    """Returns per-q-head (dk, dv) of shape (B, H, T, hd)."""
    b, h, s, hd = q.shape
    kv, t = k.shape[1], k.shape[2]
    group = h // kv
    n_q, n_k = s // block_q, t // block_k
    kernel = functools.partial(
        _dkv_kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        n_q=n_q, causal=causal, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, ki, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, qi: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, qi: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, ki, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, ki, qi: (bi, hi, qi)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, ki, qi: (bi, hi, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, hd), q.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary"), interpret
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
