"""Pure-jnp oracle for flash attention (full score matrix, exact softmax).

Shares the implementation with ``models.attention.naive_attention`` — that
function *is* the reference semantics the kernel must match.
"""

from __future__ import annotations

from ...models.attention import naive_attention as attention_ref

__all__ = ["attention_ref"]
