"""Fused RMSNorm Pallas TPU kernel (forward + input/weight gradients).

One HBM round-trip per tensor: rows are blocked (rows_block, D) into VMEM, the
f32 reduction happens in-register, and the scaled output is written back in the
input dtype.  The backward kernel accumulates dw across row blocks in VMEM
scratch over the sequential grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret, tpu_compiler_params

__all__ = ["rmsnorm_fwd", "rmsnorm_bwd"]


def _fwd_kernel(x_ref, w_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(y_ref.dtype)


def rmsnorm_fwd(x: jax.Array, w: jax.Array, eps: float = 1e-5,
                rows_block: int = 128, interpret=None) -> jax.Array:
    """x: (N, D) row-major; w: (D,)."""
    n, d = x.shape
    interpret = default_interpret(interpret)
    if n % rows_block != 0:
        rows_block = n
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(n // rows_block,),
        in_specs=[
            pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        compiler_params=tpu_compiler_params(("arbitrary",), interpret),
        interpret=interpret,
    )(x, w)


def _bwd_kernel(x_ref, w_ref, dy_ref, dx_ref, dw_ref, dw_scr, *, eps, n_blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw_scr[...] = jnp.zeros_like(dw_scr)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = x * r
    dxhat = dy * w
    d_inner = jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    dx = (dxhat - xhat * d_inner) * r
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dw_scr[...] += jnp.sum(dy * xhat, axis=0)

    @pl.when(i == n_blocks - 1)
    def _finish():
        dw_ref[...] = dw_scr[...]


def rmsnorm_bwd(x: jax.Array, w: jax.Array, dy: jax.Array, eps: float = 1e-5,
                rows_block: int = 128, interpret=None) -> tuple[jax.Array, jax.Array]:
    n, d = x.shape
    interpret = default_interpret(interpret)
    if n % rows_block != 0:
        rows_block = n
    n_blocks = n // rows_block
    dx, dw = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps, n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        compiler_params=tpu_compiler_params(("arbitrary",), interpret),
        interpret=interpret,
    )(x, w, dy)
    return dx, dw
