"""jit'd wrapper for fused RMSNorm with custom VJP (model layout (..., D))."""

from __future__ import annotations

import functools

import jax

from . import kernel as K
from ..common import default_interpret


__all__ = ["rms_norm_fused"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x2d, w, eps, interpret):
    return K.rmsnorm_fwd(x2d, w, eps=eps, interpret=interpret)


def _fwd(x2d, w, eps, interpret):
    return K.rmsnorm_fwd(x2d, w, eps=eps, interpret=interpret), (x2d, w)


def _bwd(eps, interpret, res, dy):
    x2d, w = res
    dx, dw = K.rmsnorm_bwd(x2d, w, dy, eps=eps, interpret=interpret)
    return dx, dw.astype(w.dtype)


_rmsnorm.defvjp(_fwd, _bwd)


def rms_norm_fused(
    x: jax.Array, w: jax.Array, eps: float = 1e-5, interpret: bool | None = None
) -> jax.Array:
    """Fused RMSNorm over the last axis; any leading shape."""
    interpret = default_interpret(interpret)
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    return _rmsnorm(x2d, w, eps, interpret).reshape(shape)
