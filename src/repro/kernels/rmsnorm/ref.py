"""Pure-jnp oracle for fused RMSNorm (same math as models.layers.rms_norm)."""

from __future__ import annotations

from ...models.layers import rms_norm as rms_norm_ref

__all__ = ["rms_norm_ref"]
