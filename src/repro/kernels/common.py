"""Shared Pallas utilities: platform dispatch + compiler-params shims."""

from __future__ import annotations

from collections.abc import Sequence

import jax

__all__ = ["default_interpret", "tpu_compiler_params"]


def default_interpret(interpret: bool | None) -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends.

    This container is CPU-only: interpret=True executes the kernel body with
    jnp semantics (correctness validation); on a real TPU the same code lowers
    through Mosaic.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def tpu_compiler_params(dimension_semantics: Sequence[str], interpret: bool):
    """CompilerParams with dimension semantics (None in interpret mode)."""
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=tuple(dimension_semantics))
