"""RG-LRU Pallas TPU kernel: diagonal linear recurrence with VMEM-resident state.

grid = (B, n_d_blocks, n_chunks); the channel dimension is blocked (parallel)
and chunks advance sequentially ("arbitrary") with the (1, d_block) state held
in VMEM scratch.  Token loop inside the chunk is a fori_loop over rows of the
(chunk, d_block) tile — elementwise vector work; the op is bandwidth-bound and
streams a/b tiles from HBM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret, tpu_compiler_params

__all__ = ["rglru_pallas"]


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, state_scr, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)  # (1, D)

    a = a_ref[0].astype(jnp.float32)  # (C, D)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, y = carry  # h: (1, D); y: (C, D)
        a_t = jax.lax.dynamic_slice_in_dim(a, t, 1, 0)
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, 0)
        h = a_t * h + b_t
        y = jax.lax.dynamic_update_slice_in_dim(y, h, t, 0)
        return h, y

    h, y = jax.lax.fori_loop(
        0, chunk, step, (state_scr[...], jnp.zeros_like(y_ref[0], jnp.float32))
    )
    state_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hout_ref[0] = h


def rglru_pallas(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array | None = None,
    chunk: int = 128,
    d_block: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """a, b: (B, S, D); h0: (B, D). Returns (h (B,S,D), final (B,D))."""
    bsz, s, d = a.shape
    interpret = default_interpret(interpret)
    if s % chunk != 0:
        chunk = s
    if d % d_block != 0:
        d_block = d
    n_chunks = s // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, d), jnp.float32)
    h0 = h0.astype(jnp.float32).reshape(bsz, 1, d)

    kernel = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=n_chunks)
    y, hout = pl.pallas_call(
        kernel,
        grid=(bsz, d // d_block, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, 1, d_block), lambda bi, di, ci: (bi, 0, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, 1, d_block), lambda bi, di, ci: (bi, 0, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
            jax.ShapeDtypeStruct((bsz, 1, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, d_block), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary"), interpret
        ),
        interpret=interpret,
    )(a, b, h0)
    return y, hout[:, 0]
