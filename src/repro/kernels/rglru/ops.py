"""RG-LRU linear recurrence ops: parallel associative scan (default) and the
Pallas TPU kernel.

The recurrence ``h_t = a_t h_{t-1} + b_t`` is the composition of affine maps;
``jax.lax.associative_scan`` evaluates all prefixes in O(log S) depth — the
standard TPU-native realization of a diagonal RNN (what Griffin itself uses),
in contrast to GPU implementations that rely on a hand-written sequential CUDA
kernel.  The initial state is folded into the first element.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import linear_recurrence_ref


__all__ = ["linear_recurrence"]


def linear_recurrence_assoc(
    a: jax.Array, b: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, hs = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return hs.astype(a.dtype), hs[:, -1]


def linear_recurrence(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array | None = None,
    impl: str = "assoc",
) -> tuple[jax.Array, jax.Array]:
    if impl == "ref":
        return linear_recurrence_ref(a, b, h0)
    if impl == "assoc":
        return linear_recurrence_assoc(a, b, h0)
    if impl == "pallas":
        from .kernel import rglru_pallas

        return rglru_pallas(a, b, h0)
    raise ValueError(f"unknown rglru impl {impl!r}")
