"""Pure-jnp oracle for the RG-LRU diagonal gated linear recurrence
(RecurrentGemma / Griffin, arXiv:2402.19427):

    h_t = a_t ⊙ h_{t-1} + b_t,       a_t ∈ (0,1)^D

where the caller supplies ``a`` (data-dependent decay) and ``b`` (gated input,
already scaled by sqrt(1−a²)).  Sequential scan over time; f32 state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = ["linear_recurrence_ref"]


def linear_recurrence_ref(
    a: jax.Array, b: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """a, b: (B, S, D); h0: (B, D). Returns (h (B,S,D), final (B,D))."""
    bsz, _, d = a.shape
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, d), jnp.float32)

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    final, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                             (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), final
