"""End-to-end system tests: the scheduled training loop with adaptive
checkpointing, restart determinism (fault tolerance), serving engine, and
straggler detection."""


import jax
import numpy as np
import pytest

from repro.core.params import reset_param_registry
from repro.core.timers import reset_timer_db
from repro.launch.train import TrainSettings, run_training
from repro.serving import Request, ServeSession, ServiceLevel


def _settings(tmp_path, steps, **kw):
    base = dict(
        arch="llama3.2-1b", smoke=True, steps=steps, global_batch=2, seq_len=32,
        ckpt_dir=str(tmp_path / "ckpt"), ckpt_mode="adaptive",
        ckpt_max_fraction=0.5, ckpt_max_interval_s=1e9, report_every=0,
    )
    base.update(kw)
    return TrainSettings(**base)


def _fresh():
    reset_timer_db()
    reset_param_registry()


def test_training_loop_runs_and_profiles(tmp_path):
    summary = run_training(_settings(tmp_path, steps=6))
    assert summary["iterations"] == 6
    assert np.isfinite(summary["final_metrics"]["loss"])
    bins = summary["bin_seconds"]
    assert bins["EVOL"] > 0 and bins["STARTUP"] > 0
    assert summary["checkpoint"]["n_checkpoints"] >= 1
    # the hierarchical profile: >=3-deep scope nesting with consistent
    # inclusive/exclusive seconds (simulation/total -> bin -> routine -> scope)
    def depth(row):
        return 1 + max((depth(c) for c in row["children"]), default=0)

    def check(row):
        child_sum = sum(c["inclusive_s"] for c in row["children"])
        assert child_sum <= row["inclusive_s"] + 1e-9, row["timer"]
        assert row["exclusive_s"] == pytest.approx(row["inclusive_s"] - child_sum)
        for c in row["children"]:
            check(c)

    forest = {row["timer"]: row for row in summary["timer_tree"]}
    total = forest["simulation/total"]
    assert depth(total) >= 3
    for row in summary["timer_tree"]:
        check(row)
    # the compile scope nests under the STARTUP driver routine
    startup_bin = next(c for c in total["children"] if c["timer"] == "bin/STARTUP")
    driver = next(
        c for c in startup_bin["children"] if c["timer"] == "STARTUP/driver::startup"
    )
    assert any(c["timer"] == "STARTUP/compile" for c in driver["children"])


@pytest.mark.slow
def test_loss_decreases_on_learnable_task(tmp_path):
    _fresh()
    summary = run_training(
        _settings(tmp_path, steps=60, ckpt_mode="off", peak_lr=1e-2, seq_len=64,
                  global_batch=4, data_mode="skewed")
    )
    # uniform init -> ce = ln(256) = 5.55; the Zipf unigram is learnable fast
    assert summary["final_metrics"]["ce"] < 4.9


@pytest.mark.slow
def test_restart_determinism(tmp_path):
    """Fault tolerance: kill after N steps, restore, and land on the *same*
    final loss as an uninterrupted run (bitwise-deterministic substrate)."""
    # uninterrupted 8 steps
    _fresh()
    full = run_training(_settings(tmp_path / "a", steps=8, ckpt_max_fraction=1.0,
                                  lr_total_steps=8))
    # interrupted: 4 steps, then resume to 8 from the checkpoint (same LR horizon)
    _fresh()
    run_training(_settings(tmp_path / "b", steps=4, ckpt_max_fraction=1.0,
                           lr_total_steps=8))
    _fresh()
    resumed = run_training(_settings(tmp_path / "b", steps=8, ckpt_max_fraction=1.0,
                                     lr_total_steps=8))
    assert resumed["iterations"] == 8
    np.testing.assert_allclose(
        resumed["final_metrics"]["loss"], full["final_metrics"]["loss"], rtol=1e-5
    )


@pytest.mark.slow
def test_adaptive_bound_respected_with_slow_ckpt(tmp_path):
    """With an artificially slow (synchronous) writer, AdaptCheck keeps the
    checkpoint fraction near the bound while fixed-interval blows through it."""
    _fresh()
    adaptive = run_training(_settings(
        tmp_path / "ad", steps=12, ckpt_mode="adaptive", ckpt_max_fraction=0.10,
        ckpt_synchronous=True, ckpt_delay_s=0.05,
    ))
    _fresh()
    fixed = run_training(_settings(
        tmp_path / "fx", steps=12, ckpt_mode="fixed", ckpt_every=1,
        ckpt_synchronous=True, ckpt_delay_s=0.05,
    ))
    # weak bound on a short run: early checkpoints may overshoot, but the
    # controller must suppress and end up well below the every-step baseline
    assert adaptive["checkpoint"]["n_suppressed"] > 0
    assert adaptive["checkpoint"]["n_checkpoints"] < fixed["checkpoint"]["n_checkpoints"]
    # proper bound adherence over long horizons is validated in
    # benchmarks/bench_adaptive_checkpoint.py (Fig. 3 reproduction)


def test_serving_engine_completes_and_steers():
    _fresh()

    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeSession(
        cfg, params, n_slots=4, max_seq=64,
        slo=ServiceLevel(target_decode_ms=1e-6),  # impossible target -> steer down
    )
    rng = np.random.default_rng(0)
    handles = [
        engine.submit(Request(rid, list(rng.integers(0, cfg.vocab_size, 16)), max_new_tokens=4))
        for rid in range(8)
    ]
    done = engine.run_until_idle()
    assert len(done) == 8
    assert all(h.done and len(h.result().tokens) == 4 for h in handles)
    assert engine.max_active < 4  # steered down due to impossible latency target
    stats = engine.stats()
    assert stats["completed"] == 8.0
    # the steering happened ON the control plane: ADAPT rows in the decision log
    shrinks = [a for a in engine.control_loop.actions if a.action == "shrink_batch"]
    assert shrinks and all(a.controller == "serving" for a in shrinks)


def test_straggler_detection():
    from repro.dist.stragglers import StragglerDetector

    hits = []
    det = StragglerDetector(n_hosts=4, window=8, threshold=1.5,
                            on_straggler=lambda r: hits.append(r))
    for _ in range(8):
        for host in range(4):
            det.observe(host, 1.0 if host != 2 else 3.0)
    report = det.check(step=8)
    assert report.stragglers == [2]
    assert hits and hits[0].stragglers == [2]
