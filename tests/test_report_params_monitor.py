"""Reports (Fig 2), steerable parameters (Sec 5), HTTP monitor (Sec 3.1)."""

import json
import time
import urllib.request

import pytest

from repro.core.params import ParamError, param_registry
from repro.core.report import TimerLogger, bin_distribution, format_report, report_rows
from repro.core.timers import timer_db
from repro.monitor import MonitorServer, StatusWriter


def _populate_db():
    db = timer_db()
    for name in ("EVOL/trainer::step", "CHECKPOINT/adaptcheck::write", "simulation/total"):
        h = db.create(name)
        db.start(h); time.sleep(0.002); db.stop(h)
    return db


def test_format_report_contains_rows_and_total():
    db = _populate_db()
    text = format_report(db)
    assert "EVOL/trainer::step" in text
    assert "Total time for simulation" in text


def test_report_rows_filter_prefix():
    db = _populate_db()
    rows = report_rows(db, prefix="EVOL/")
    assert len(rows) == 1 and rows[0]["timer"] == "EVOL/trainer::step"


def test_bin_distribution():
    db = timer_db()
    for b in ("EVOL", "CHECKPOINT"):
        h = db.create(f"bin/{b}")
        db.start(h); time.sleep(0.002); db.stop(h)
    dist = bin_distribution(db)
    assert set(dist) == {"EVOL", "CHECKPOINT"} and all(v > 0 for v in dist.values())


def test_timer_logger_roundtrip(tmp_path):
    db = _populate_db()
    logger = TimerLogger(str(tmp_path / "timers.jsonl"), db)
    logger.log(1)
    logger.log(2, extra={"loss": 1.5})
    records = logger.read_all()
    assert len(records) == 2
    assert records[1]["extra"]["loss"] == 1.5
    assert "EVOL/trainer::step" in records[0]["timers"]


def test_param_registry_steering():
    reg = param_registry()
    reg.declare("ckpt.max_fraction", 0.05, steerable=True,
                validator=lambda v: 0 < v <= 1)
    reg.declare("model.layers", 4, steerable=False)
    reg.freeze()
    reg.set("ckpt.max_fraction", 0.10, iteration=7)
    assert reg.get("ckpt.max_fraction") == 0.10
    with pytest.raises(ParamError):
        reg.set("model.layers", 8)  # frozen non-steerable
    with pytest.raises(ParamError):
        reg.set("ckpt.max_fraction", 2.0)  # fails validation
    desc = {d["name"]: d for d in reg.describe()}
    assert desc["ckpt.max_fraction"]["n_changes"] == 1


def test_status_writer_atomic(tmp_path):
    db = _populate_db()
    w = StatusWriter(str(tmp_path / "status.json"), db)
    w.write({"iteration": 3})
    payload = json.load(open(tmp_path / "status.json"))
    assert payload["status"]["iteration"] == 3
    assert "simulation/total" in payload["timers"]


def test_format_tree_report_and_rows():
    from repro.core.report import format_tree_report, tree_rows
    from repro.core.timers import timer_db as _tdb

    db = _tdb()
    with db.scope("run"):
        with db.scope("phase"):
            time.sleep(0.002)
    text = format_tree_report(db)
    lines = text.splitlines()
    assert any(line.startswith("run ") for line in lines)
    assert any(line.startswith("  run/phase ") for line in lines)
    (root,) = tree_rows(db, prefix="run")
    assert root["children"][0]["timer"] == "run/phase"
    assert root["children"][0]["inclusive_s"] <= root["inclusive_s"]


def test_monitor_http_endpoints():
    db = _populate_db()
    reg = param_registry()
    reg.declare("serving.max_batch", 8, steerable=True)
    srv = MonitorServer(0, db, reg, status_fn=lambda: {"iteration": 5})
    port = srv.start()
    try:
        base = f"http://127.0.0.1:{port}"
        timers = json.loads(urllib.request.urlopen(base + "/timers").read())
        assert "simulation/total" in timers
        tree = json.loads(urllib.request.urlopen(base + "/tree").read())
        tree_names = {row["timer"] for row in tree}
        assert "simulation/total" in tree_names
        assert all({"inclusive_s", "exclusive_s", "children"} <= set(r) for r in tree)
        status = json.loads(urllib.request.urlopen(base + "/status").read())
        assert status["iteration"] == 5
        html = urllib.request.urlopen(base + "/").read().decode()
        assert "Timer report" in html
        # steering via POST (paper Sec. 5)
        req = urllib.request.Request(
            base + "/params", data=json.dumps({"name": "serving.max_batch", "value": 4}).encode(),
            method="POST",
        )
        resp = json.loads(urllib.request.urlopen(req).read())
        assert resp["ok"] and reg.get("serving.max_batch") == 4
    finally:
        srv.stop()
