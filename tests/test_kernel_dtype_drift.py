"""Kernel-vs-reference dtype drift: bf16 inputs through the Pallas
interpret-mode fwd/bwd paths must come back in the *same* dtype on both sides
of the comparison — a silent f32 promotion on one side only would make
tolerance checks (and the pipelined model's activation contract) lie.

Parametrized per kernel family over {bf16, f32}, pinning
  * forward output dtypes kernel == reference == input dtype
    (recurrence states are f32 by design, on BOTH sides),
  * vjp cotangent dtypes kernel == reference == input dtype,
  * value agreement at per-family tolerances (the repo-wide convention:
    bf16 2e-2 .. 3e-2, f32 2e-5 .. 2e-4).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru.ops import linear_recurrence
from repro.kernels.rmsnorm.ops import rms_norm_fused
from repro.kernels.rwkv6.ops import wkv6
from repro.models.attention import attention
from repro.models.layers import rms_norm

DTYPES = [jnp.float32, jnp.bfloat16]


def _tols(dtype, f32_tol, bf16_tol):
    t = bf16_tol if dtype == jnp.bfloat16 else f32_tol
    return dict(atol=t, rtol=t)


def _assert_close(a, b, **tol):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **tol
    )


def _check_pair(kernel_fn, ref_fn, inputs, *, fwd_tol, state_is_f32=False):
    """fwd + vjp: dtype equality on both sides, values within tolerance."""
    dtype = inputs[0].dtype
    out_k = kernel_fn(*inputs)
    out_r = ref_fn(*inputs)
    outs_k = out_k if isinstance(out_k, tuple) else (out_k,)
    outs_r = out_r if isinstance(out_r, tuple) else (out_r,)
    for i, (yk, yr) in enumerate(zip(outs_k, outs_r)):
        expect = jnp.float32 if (state_is_f32 and i > 0) else dtype
        assert yk.dtype == expect, f"kernel out[{i}]: {yk.dtype} != {expect}"
        assert yr.dtype == expect, f"ref out[{i}]: {yr.dtype} != {expect}"
        _assert_close(yk, yr, **fwd_tol)

    # vjp through the primary output only (states are carried, not lossed)
    def scalarize(fn):
        def f(*args):
            out = fn(*args)
            y = out[0] if isinstance(out, tuple) else out
            return jnp.sum(y.astype(jnp.float32) ** 2)

        return f

    gk = jax.grad(scalarize(kernel_fn), argnums=tuple(range(len(inputs))))(*inputs)
    gr = jax.grad(scalarize(ref_fn), argnums=tuple(range(len(inputs))))(*inputs)
    for i, (dk, dr) in enumerate(zip(gk, gr)):
        assert dk.dtype == inputs[i].dtype, (
            f"kernel grad[{i}] promoted: {dk.dtype} != {inputs[i].dtype}"
        )
        assert dr.dtype == inputs[i].dtype, (
            f"ref grad[{i}] promoted: {dr.dtype} != {inputs[i].dtype}"
        )
    return gk, gr


@pytest.mark.parametrize("dtype", DTYPES)
def test_attention_flash_vs_naive(dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, hd = 2, 128, 2, 16  # s % 128 == 0: the real Pallas tiling path
    q = (jax.random.normal(k1, (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (b, s, h, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (b, s, h, hd)) * 0.5).astype(dtype)
    gk, gr = _check_pair(
        lambda q, k, v: flash_attention(q, k, v, causal=True),
        lambda q, k, v: attention(q, k, v, impl="naive", causal=True),
        (q, k, v),
        fwd_tol=_tols(dtype, 2e-5, 2e-2),
    )
    tol = _tols(dtype, 2e-4, 3e-2)
    for a, b_ in zip(gk, gr):
        _assert_close(a, b_, **tol)


@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_fused_vs_ref(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = (jax.random.normal(k1, (4, 32, 64))).astype(dtype)
    w = (1.0 + 0.1 * jax.random.normal(k2, (64,))).astype(dtype)
    gk, gr = _check_pair(
        lambda x, w: rms_norm_fused(x, w, 1e-5),
        lambda x, w: rms_norm(x, w, 1e-5),
        (x, w),
        fwd_tol=_tols(dtype, 2e-5, 2e-2),
    )
    tol = _tols(dtype, 2e-4, 3e-2)
    for a, b_ in zip(gk, gr):
        _assert_close(a, b_, **tol)


@pytest.mark.parametrize("dtype", DTYPES)
def test_rglru_assoc_vs_ref(dtype):
    """The two differentiable impls (the Pallas rglru kernel is fwd-only:
    decode/bench path, no vjp rule).  State output stays f32 on BOTH sides."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    b, s, d = 2, 64, 32
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d))).astype(dtype)
    drive = (jax.random.normal(k2, (b, s, d)) * 0.1).astype(dtype)
    gk, gr = _check_pair(
        lambda a, x: linear_recurrence(a, x, None, impl="assoc"),
        lambda a, x: linear_recurrence(a, x, None, impl="ref"),
        (a, drive),
        fwd_tol=_tols(dtype, 2e-4, 3e-2),
        state_is_f32=True,
    )
    tol = _tols(dtype, 2e-4, 3e-2)
    for x, y in zip(gk, gr):
        _assert_close(x, y, **tol)


@pytest.mark.parametrize("dtype", DTYPES)
def test_wkv6_chunked_vs_ref(dtype):
    """Grads are compared w.r.t. the *log-decay* — the parametrization the
    model actually differentiates (blocks.py: ``w = exp(-exp(clip(...)))``).
    Comparing dL/dw directly is ill-posed for near-zero decays: the chunked
    form works in log space, so its dL/dw carries a ``1/w`` factor whose
    rounding error explodes exactly where ``w`` underflows; the ``· w``
    chain-rule factor of the log parametrization cancels it on both sides."""
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, hd = 2, 64, 2, 8  # s % chunk == 0: the real chunked path
    r = (jax.random.normal(k1, (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (b, s, h, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (b, s, h, hd)) * 0.5).astype(dtype)
    logw = -jnp.exp(
        jnp.clip(jax.random.normal(k4, (b, s, h, hd)), -8.0, 8.0)
    ).astype(jnp.float32)
    u = (jax.random.normal(k5, (h, hd)) * 0.5).astype(jnp.float32)

    def run(impl):
        def f(r, k, v, lw):
            return wkv6(
                r, k, v, jnp.exp(lw).astype(r.dtype), u, None,
                impl=impl, chunk=32,
            )

        return f

    gk, gr = _check_pair(
        run("chunked"), run("ref"), (r, k, v, logw),
        fwd_tol=_tols(dtype, 2e-4, 3e-2),
        state_is_f32=True,
    )
    tol = _tols(dtype, 2e-3, 3e-2)
    for a, b_ in zip(gk, gr):
        _assert_close(a, b_, **tol)
