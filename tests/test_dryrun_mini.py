"""Mini dry-run in a subprocess (8 placeholder devices): proves the
lower+compile+analyze path on small meshes without touching this process's
device count.  Also exercises shard_map pipeline parallelism and the
compressed cross-pod all-reduce on a multi-axis mesh."""

import json
import os
import subprocess
import sys

import pytest

# compiles 8 mini dry-run cells in a forced 8-device subprocess (~1 min)
pytestmark = [pytest.mark.slow, pytest.mark.multihost]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MINI_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

assert jax.device_count() == 8

from repro.configs import get_smoke_config
from repro.dist.compat import cost_analysis
from repro.dist.sharding import DEFAULT_RULES
from repro.launch.hlo import collective_bytes
from repro.launch.steps import build_step, input_specs, rules_for
from repro.models.config import ShapeConfig

out = {}

# --- mini multi-pod dry-run: (pod, data, model) = (2, 2, 2) -----------------
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ["llama3.2-1b", "moonshot-v1-16b-a3b", "recurrentgemma-9b", "rwkv6-1.6b"]:
    cfg = get_smoke_config(arch)
    for kind, shape in [
        ("train", ShapeConfig("t", "train", 64, 8)),
        ("decode", ShapeConfig("d", "decode", 64, 8)),
    ]:
        built = build_step(cfg, mesh, rules_for(cfg), shape)
        with mesh:
            args = [built.abstract_state["params"]]
            if kind == "train":
                args.append(built.abstract_state["opt_state"])
            compiled = built.fn.lower(*args, *built.abstract_inputs).compile()
        coll = collective_bytes(compiled.as_text())
        out[f"{arch}:{kind}"] = {
            "collective_bytes": sum(coll.values()),
            "flops": cost_analysis(compiled).get("flops", -1.0),
        }

# --- pipeline parallelism over the pod axis ---------------------------------
from repro.dist.pipeline import gpipe_forward

d = 16
n_stages = 2
key = jax.random.PRNGKey(0)
stage_w = jax.random.normal(key, (n_stages, d, d), jnp.float32) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, d), jnp.float32)

def layer_fn(w, xm):
    return jnp.tanh(xm @ w)

pp_mesh = jax.make_mesh((2, 4), ("pod", "data"))
y_pp = gpipe_forward(layer_fn, stage_w, x, mesh=pp_mesh, axis="pod", n_micro=4)
y_ref = layer_fn(stage_w[1], layer_fn(stage_w[0], x))
out["pipeline_max_err"] = float(jnp.max(jnp.abs(y_pp - y_ref)))

# --- compressed cross-pod reduction inside shard_map -------------------------
from repro.dist.compat import shard_map
from repro.optim.compression import cross_pod_mean_compressed, ef_init

g = jax.random.normal(jax.random.PRNGKey(2), (2, 64), jnp.float32)  # per-pod grads

def reducer(g_local, ef):
    mean, new_ef = cross_pod_mean_compressed({"g": g_local[0]}, ef, "pod")
    return mean["g"], new_ef

ef0 = ef_init({"g": g[0]})
fn = shard_map(
    reducer, mesh=pp_mesh, in_specs=(P("pod"), P()), out_specs=(P(), P()),
    check=False,
)
mean, _ = fn(g, ef0)
true_mean = jnp.mean(g, axis=0)
out["compressed_allreduce_err"] = float(jnp.max(jnp.abs(mean - true_mean)))

print("MINI_RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mini_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", MINI_SCRIPT],
        capture_output=True, text=True, timeout=1200, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, f"mini dryrun failed:\n{proc.stdout}\n{proc.stderr}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("MINI_RESULT ")][-1]
    return json.loads(line[len("MINI_RESULT "):])


def test_mini_dryrun_cells_compile_with_collectives(mini_result):
    for key in ["llama3.2-1b:train", "moonshot-v1-16b-a3b:train",
                "recurrentgemma-9b:decode", "rwkv6-1.6b:decode"]:
        assert key in mini_result
        assert mini_result[key]["flops"] > 0
    # training on a sharded mesh must produce gradient collectives
    assert mini_result["llama3.2-1b:train"]["collective_bytes"] > 0


def test_pipeline_parallel_matches_reference(mini_result):
    assert mini_result["pipeline_max_err"] < 1e-5


def test_compressed_cross_pod_allreduce_accuracy(mini_result):
    # int8 quantization: ~1% of the max-abs scale
    assert mini_result["compressed_allreduce_err"] < 0.05
