"""The ``repro.timing`` facade: hierarchical scopes, handles, counters,
sessions, tree aggregation, and the deprecation shims over the old surface."""

import time

import pytest

from repro import timing
from repro.core import clocks as C
from repro.core.timers import TimerError, path_matches, timer_db


# ---------------------------------------------------------------------------
# scopes
# ---------------------------------------------------------------------------

def test_scope_paths_nest_via_runtime_stack():
    with timing.scope("train"):
        with timing.scope("step"):
            with timing.scope("forward"):
                pass
            with timing.scope("backward"):
                pass
    db = timer_db()
    assert db.exists("train/step/forward") and db.exists("train/step/backward")
    assert db.get("train/step/forward").parent_name == "train/step"
    assert db.get("train/step").parent_name == "train"
    assert db.get("train").parent_name is None


def test_scope_reuses_timer_across_entries():
    for _ in range(3):
        with timing.scope("outer"):
            with timing.scope("inner"):
                pass
    db = timer_db()
    assert db.get("outer/inner").count == 3
    assert db.get("outer").count == 3


def test_scope_name_may_contain_segments():
    with timing.scope("serve"):
        with timing.scope("phase/admit"):
            pass
    assert timer_db().exists("serve/phase/admit")


def test_scope_handle_absolute_path_and_dynamic_parent():
    h = timing.scope_handle("train/step")
    with h:
        pass
    db = timer_db()
    assert db.get("train/step").parent_name is None  # entered at top level
    with timing.scope("warmup"):
        with h:  # same handle, different enclosing scope
            pass
    assert db.get("train/step").parent_name == "warmup"
    assert db.get("train/step").count == 2


def test_scope_handle_is_cached_per_path():
    assert timing.scope_handle("a/b") is timing.scope_handle("a/b")
    db2 = timing.TimerDB()
    assert timing.scope_handle("a/b", db=db2) is not timing.scope_handle("a/b")


def test_scope_handle_nests_scopes_under_it():
    h = timing.scope_handle("serve")
    with h:
        with timing.scope("admit"):
            pass
    assert timer_db().get("serve/admit").parent_name == "serve"


def test_scope_handle_double_enter_raises():
    h = timing.scope_handle("once")
    with h:
        with pytest.raises(TimerError):
            h.__enter__()


def test_timed_records_under_callers_active_scope():
    @timing.timed("build")
    def build():
        time.sleep(0.001)

    build()  # bare: top-level path
    with timing.scope("train"):
        build()  # nested path
    db = timer_db()
    assert db.get("build").count == 1
    assert db.get("train/build").count == 1
    assert db.get("train/build").parent_name == "train"


def test_timed_default_label_is_qualname():
    @timing.timed()
    def helper():
        pass

    helper()
    names = timer_db().names()
    assert any(n.endswith("helper") for n in names)


def test_counter_namespaced_under_resolution_scope():
    base_scoped = C.counter_channel("serve/tokens")
    base_raw = C.counter_channel("tokens")  # global channel; other tests bump it
    with timing.scope("serve"):
        bump = timing.counter("tokens")
    bump(3.0)
    bump(4.0)
    assert C.counter_channel("serve/tokens") - base_scoped == 7.0
    # absolute addressing skips the namespace
    raw = timing.counter("tokens", absolute=True)
    raw(5.0)
    assert C.counter_channel("tokens") - base_raw == 5.0


def test_current_scope():
    assert timing.current_scope() == ""
    with timing.scope("a"):
        with timing.scope("b"):
            assert timing.current_scope() == "a/b"
    assert timing.current_scope() == ""


# ---------------------------------------------------------------------------
# tree aggregation
# ---------------------------------------------------------------------------

def test_tree_inclusive_exclusive_identity():
    with timing.scope("root"):
        time.sleep(0.002)
        with timing.scope("child1"):
            time.sleep(0.004)
        with timing.scope("child2"):
            time.sleep(0.002)
    roots = {n.name: n for n in timing.tree()}
    root = roots["root"]
    assert [c.name for c in root.children] == ["root/child1", "root/child2"]
    child_sum = sum(c.inclusive for c in root.children)
    assert root.exclusive == pytest.approx(root.inclusive - child_sum)
    assert 0.0 <= root.exclusive < root.inclusive
    assert child_sum <= root.inclusive
    leaf = root.children[0]
    assert leaf.exclusive == pytest.approx(leaf.inclusive)
    assert root.depth == 2


def test_tree_renders_three_deep():
    with timing.scope("a"):
        with timing.scope("b"):
            with timing.scope("c"):
                time.sleep(0.001)
    text = timing.format_tree()
    lines = text.splitlines()
    assert any(line.startswith("a ") for line in lines)
    assert any(line.startswith("  a/b ") for line in lines)
    assert any(line.startswith("    a/b/c ") for line in lines)
    root = next(n for n in timing.tree() if n.name == "a")
    assert root.depth == 3


def test_tree_rows_nested_json():
    from repro.core.report import tree_rows

    with timing.scope("x"):
        with timing.scope("y"):
            pass
    rows = tree_rows(timer_db(), prefix="x")
    assert len(rows) == 1
    assert rows[0]["timer"] == "x"
    (child,) = rows[0]["children"]
    assert child["timer"] == "x/y"
    assert child["inclusive_s"] <= rows[0]["inclusive_s"]


def test_tree_splits_timer_entered_under_multiple_parents():
    """A shared scope entered under two different parents (e.g. the final
    checkpoint write running in SHUTDOWN) must split into per-call-path nodes
    carrying exactly the seconds accrued under each — keeping the
    sum(child.inclusive) <= parent.inclusive invariant everywhere."""
    shared = timing.scope_handle("shared/write")
    for _ in range(3):
        with timing.scope("loop"):
            with shared:
                time.sleep(0.001)
    with timing.scope("final"):
        with shared:
            time.sleep(0.002)
    db = timer_db()
    stats = db.get("shared/write").parent_stats()
    assert stats[("loop",)][1] == 3 and stats[("final",)][1] == 1
    nodes = {n.name: n for n in timing.tree()}
    loop_node, final_node = nodes["loop"], nodes["final"]
    (w_loop,) = loop_node.children
    (w_final,) = final_node.children
    assert w_loop.name == w_final.name == "shared/write"
    assert w_loop.count == 3 and w_final.count == 1
    assert w_loop.inclusive <= loop_node.inclusive
    assert w_final.inclusive <= final_node.inclusive
    assert w_loop.inclusive + w_final.inclusive == pytest.approx(
        db.get("shared/write").seconds(), rel=1e-6
    )


def test_tree_split_timer_sub_scopes_follow_their_call_path():
    """Sub-scopes opened inside a shared scope land under the matching split
    node, never inflating the other parent's subtree (exclusive seconds stay
    non-negative everywhere)."""
    shared = timing.scope_handle("shared")
    for _ in range(3):
        with timing.scope("loop"):
            with shared:
                with timing.scope("sub"):
                    pass
    with timing.scope("final"):
        with shared:
            with timing.scope("sub"):
                time.sleep(0.005)
    nodes = {n.name: n for n in timing.tree()}

    def walk_check(node):
        child_sum = sum(c.inclusive for c in node.children)
        assert child_sum <= node.inclusive + 1e-9, node.name
        assert node.exclusive == pytest.approx(node.inclusive - child_sum)
        for c in node.children:
            walk_check(c)

    for name in ("loop", "final"):
        walk_check(nodes[name])
        (shared_node,) = nodes[name].children
        assert shared_node.name == "shared"
        (sub_node,) = shared_node.children
        assert sub_node.name == "shared/sub"
    loop_sub = nodes["loop"].children[0].children[0]
    final_sub = nodes["final"].children[0].children[0]
    assert loop_sub.count == 3 and final_sub.count == 1
    assert final_sub.inclusive >= 0.005  # the sleepy window is on final's path


def test_tree_prefix_selects_nested_subtrees():
    """A prefix naming a nested scope must find it wherever it sits in the
    forest (consistent with total_seconds), not return an empty report."""
    from repro.core.report import tree_rows

    with timing.scope("run"):
        with timing.scope("evol"):
            with timing.scope("step"):
                pass
    (row,) = tree_rows(timer_db(), prefix="run/evol")
    assert row["timer"] == "run/evol"
    assert row["children"][0]["timer"] == "run/evol/step"
    text = timing.format_tree(prefix="run/evol")
    assert "run/evol/step" in text and "(no timers)" not in text


def test_tree_tolerates_parent_cycles():
    db = timer_db()
    a, b = db.get(db.create("a")), db.get(db.create("b"))
    a.parent_name, b.parent_name = "b", "a"  # pathological hand-made cycle
    roots = {n.name for n in db.tree()}
    assert {"a", "b"} <= roots  # both surfaced, nothing lost, no hang


# ---------------------------------------------------------------------------
# rollups (satellite: segment matching)
# ---------------------------------------------------------------------------

def test_path_matches_whole_segments():
    assert path_matches("serve", "serve")
    assert path_matches("serve/admit", "serve")
    assert not path_matches("server_x", "serve")
    assert path_matches("EVOL/trainer::step", "EVOL/")
    assert path_matches("anything", "")


def test_total_seconds_segment_match_no_false_positive():
    db = timer_db()
    for name in ("serve", "serve/admit", "server_x"):
        h = db.create(name)
        db.start(h)
        time.sleep(0.001)
        db.stop(h)
    both = db.total_seconds("serve")
    assert both == pytest.approx(
        db.get("serve").seconds() + db.get("serve/admit").seconds()
    )
    assert db.total_seconds("server_x") > 0.0  # exact name still addressable
    assert timing.total_seconds("serve") == pytest.approx(both)


def test_report_rows_prefix_segment_match():
    from repro.core.report import report_rows

    db = timer_db()
    for name in ("serve", "serve/admit", "server_x"):
        db.create(name)
    names = {r["timer"] for r in report_rows(db, prefix="serve")}
    assert names == {"serve", "serve/admit"}


# ---------------------------------------------------------------------------
# satellite: out-of-order stops re-derive parents (overlapping windows)
# ---------------------------------------------------------------------------

def test_out_of_order_stop_reparents_later_starts():
    """The paper allows overlapping windows: a scope started under parent A
    and stopped after A must not leave stale attribution on later starts."""
    db = timer_db()
    a, b, c = db.create("A"), db.create("B"), db.create("C")
    db.start(a)
    db.start(b)                      # B under A
    db.stop(a)                       # out of order: A closes while B runs
    assert db.get(b).parent_name == "A"
    db.start(c)                      # stack is [B] now
    assert db.get(c).parent_name == "B"
    db.stop(c)
    db.stop(b)
    db.start(b)                      # top level: parent re-derived, not stale
    assert db.get(b).parent_name is None
    db.stop(b)
    # and the forest builds cleanly from the final attribution
    roots = {n.name for n in db.tree()}
    assert "B" in roots


def test_out_of_order_scope_exit_keeps_stack_consistent():
    h1, h2 = timing.scope_handle("w1"), timing.scope_handle("w2")
    h1.__enter__()
    h2.__enter__()
    h1.__exit__(None, None, None)    # overlapping, not nested exit order
    assert timing.current_scope() == "w2"
    h2.__exit__(None, None, None)
    assert timing.current_scope() == ""


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

def test_session_installs_and_restores_db():
    outer_db = timer_db()
    with timing.session() as ts:
        assert timer_db() is ts.db
        assert timer_db() is not outer_db
        assert timing.current_session() is ts
        with timing.scope("inside"):
            pass
        assert ts.db.exists("inside")
    assert timer_db() is outer_db
    assert not outer_db.exists("inside")
    assert timing.current_session() is None


def test_sessions_nest():
    with timing.session() as s1:
        with timing.session() as s2:
            assert timer_db() is s2.db
        assert timer_db() is s1.db
        assert timing.current_session() is s1


def test_session_bundles_scheduler_and_control_loop():
    from repro.core import RunState

    with timing.session() as ts:
        ts.scheduler.schedule(lambda s: None, bin="EVOL", thorn="t", name="noop")
        ts.scheduler.attach_control_loop(ts.control_loop)
        ts.scheduler.run(RunState(max_iterations=2))
        assert ts.db.get("EVOL/t::noop").count == 2
        assert ts.control_loop.polls == 2
        assert ts.total_seconds("simulation/total") > 0.0
        assert "simulation/total" in ts.report()
        assert "EVOL/t::noop" in ts.tree_report()
        # bins are children of simulation/total; routines children of bins
        root = next(n for n in ts.tree() if n.name == "simulation/total")
        assert root.depth >= 3


def test_session_scope_sugar_and_counter():
    with timing.session() as ts:
        with ts.scope("work"):
            bump = ts.counter("events")
        bump(2.0)
        assert C.counter_channel("work/events") == 2.0
        assert ts.timer("work").count == 1
        assert ts.tree_rows()[0]["timer"] == "work"


def test_scoped_counter_renders_in_reports_without_manual_clock():
    """Regression (PR-4 follow-up): ``timing.counter("serve/tokens")`` was
    write-only — bumpable, but invisible to every timer window and report —
    until a CounterClock was registered by hand.  Resolving a scoped counter
    now auto-exports its channel through the session CounterClock."""
    from repro.core.report import format_report

    with timing.session() as ts:
        with timing.scope("serve"):
            bump = timing.counter("tokens")
        # a window *around* the bumps captures the channel delta
        with timing.scope("serve"):
            bump(5.0)
            bump(7.0)
        flat = ts.db.get("serve").read_flat()
        assert flat.get("serve/tokens") == 12.0
        text = ts.report(channels=("walltime", "serve/tokens"))
        assert "serve/tokens" in text
    # the channel stays readable after the session exits (reports are often
    # formatted post-run), because the session clock is never auto-dropped
    post = format_report(ts.db, channels=("walltime", "serve/tokens"))
    assert "serve/tokens" in post
    # later windows keep exporting it
    with ts.db.scope("serve"):
        bump(1.0)
    assert ts.db.get("serve").read_flat().get("serve/tokens") == 13.0


def test_counter_never_double_exports_an_existing_channel():
    """An unscoped non-absolute counter whose name matches a channel some
    registered clock already exports (e.g. the io clock's ``io_bytes``) must
    not be re-exported through the session clock — a double export would
    collision-rename the established plain channel for every reader."""
    bump = timing.counter("io_bytes")  # no scope active: name stays io_bytes
    db = timer_db()
    h = db.create("window")
    db.start(h)
    bump(64.0)
    db.stop(h)
    flat = db.get(h).read_flat()
    assert flat.get("io_bytes") == 64.0          # plain name, un-renamed
    assert "session_counters.io_bytes" not in flat


# ---------------------------------------------------------------------------
# removed shims (deprecated in PR 4, removed in PR 8 — must stay gone so the
# one blessed path, repro.timing.scope / repro.timing.timed, is the only one)
# ---------------------------------------------------------------------------

def test_db_timing_shim_removed():
    from repro.core.timers import TimerDB

    assert not hasattr(TimerDB, "timing")
    assert not hasattr(timer_db(), "timing")


def test_core_timed_shim_removed():
    import repro.core
    import repro.core.timers

    assert not hasattr(repro.core.timers, "timed")
    assert not hasattr(repro.core, "timed")
    assert "timed" not in repro.core.timers.__all__
