"""Logical-axis sharding rules: divisibility drops, axis dedup, tree mapping."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    Axes,
    ShardingRules,
    spec_for,
    tree_shardings,
)


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh with named axes of size 1 won't exercise divisibility;
    # build an abstract mesh via mesh_utils over 1 device but declared axes.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_basic(mesh):
    spec = spec_for(("embed", "heads"), (64, 32), mesh, DEFAULT_RULES)
    assert spec == P(None, "model")


def test_axis_absent_from_mesh_dropped(mesh):
    rules = DEFAULT_RULES  # batch -> ("pod", "data"); mesh has no "pod"
    spec = spec_for(("batch", "seq"), (8, 16), mesh, rules)
    assert spec == P("data")


def test_divisibility_drop():
    big = jax.make_mesh((1, 1), ("data", "model"))
    # fake a mesh with model=16 semantics by using rules vs a dim of 2 — the
    # 1-sized axes always divide; exercise the logic with a custom rule table
    rules = ShardingRules({"kv_heads": "model"})
    spec = spec_for(("kv_heads",), (2,), big, rules)
    assert spec == P("model")  # size-1 axis divides everything


def test_axis_used_once_per_tensor(mesh):
    rules = ShardingRules({"a": "model", "b": "model"})
    spec = spec_for(("a", "b"), (4, 4), mesh, rules)
    assert spec == P("model")  # second use dropped (trailing None trimmed)


def test_multi_axis_dim(mesh):
    rules = ShardingRules({"batch": ("data", "model")})
    spec = spec_for(("batch", None), (4, 4), mesh, rules)
    assert spec == P(("data", "model"))


def test_fsdp_rules_shard_embed(mesh):
    spec = spec_for(("embed", "ffn"), (64, 128), mesh, FSDP_RULES)
    assert spec == P("data", "model")


def test_tree_shardings_with_axes_leaves(mesh):
    axes = {"w": Axes(("embed", "heads")), "scalar": Axes(()), "empty": ()}
    shapes = {
        "w": jax.ShapeDtypeStruct((8, 4), np.float32),
        "scalar": jax.ShapeDtypeStruct((), np.float32),
        "empty": (),
    }
    shardings = tree_shardings(axes, shapes, mesh, DEFAULT_RULES)
    assert shardings["w"].spec == P(None, "model")
    assert shardings["scalar"].spec == P()


def test_mismatched_rank_raises(mesh):
    with pytest.raises(ValueError):
        spec_for(("embed",), (4, 4), mesh, DEFAULT_RULES)


def test_rules_overrides():
    r = DEFAULT_RULES.with_overrides(seq="data")
    assert r.get("seq") == ("data",)
    assert DEFAULT_RULES.get("seq") == ()  # original untouched
