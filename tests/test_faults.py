"""Fault injection vs the hardened checkpoint layer: every corruption in the
matrix must be *detected at validation time* (never loaded), quarantined with
a machine-readable reason, counted, and recovered past."""

import os

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorrupt,
    CheckpointManager,
    plan_resume,
    save_checkpoint,
    scan_checkpoints,
    validate_checkpoint,
)
from repro.faults import (
    CHECKPOINT_FAULTS,
    FaultEvent,
    FaultPlan,
    apply_checkpoint_event,
    bit_flip_leaf,
    drop_commit,
    drop_leaf,
    drop_manifest,
    partial_manifest,
    seeded_rng,
    simulate_writer_kill,
    truncate_leaf,
)


def _tree(step: int = 0):
    return {
        "w": np.arange(256, dtype=np.float32) + step,
        "b": np.full((32,), float(step), np.float32),
    }


def _two_checkpoints(root) -> tuple[str, str]:
    old, _ = save_checkpoint(str(root), 1, _tree(1))
    new, _ = save_checkpoint(str(root), 2, _tree(2))
    return old, new


#: the corruption matrix: injector -> the reason validation must report
MATRIX = [
    (lambda p: bit_flip_leaf(p, 0, rng=seeded_rng(7)), "leaf_hash_mismatch"),
    (lambda p: truncate_leaf(p, 0), "leaf_size_mismatch"),
    (lambda p: drop_leaf(p, 0), "missing_leaf"),
    (drop_manifest, "missing_manifest"),
    (partial_manifest, "manifest_unreadable"),
    (drop_commit, "missing_commit"),
]


@pytest.mark.parametrize(
    "injector,reason", MATRIX, ids=[r for _, r in MATRIX]
)
def test_corruption_detected_quarantined_recovered(tmp_path, injector, reason):
    _, newest = _two_checkpoints(tmp_path)
    injector(newest)
    # 1. detected at validation time, with the right reason, without loading
    with pytest.raises(CheckpointCorrupt) as exc_info:
        validate_checkpoint(newest)
    assert exc_info.value.reason == reason
    # 2. the resume plan quarantines it (REASON.txt) and selects the fallback
    plan = plan_resume(str(tmp_path), quarantine=True)
    assert plan.selected is not None and plan.selected.step == 1
    assert [r.reason for r in plan.corrupt] == [reason]
    quarantined = os.path.join(str(tmp_path), "corrupt", "step_00000002")
    assert os.path.isdir(quarantined)
    with open(os.path.join(quarantined, "REASON.txt")) as f:
        assert reason in f.read()
    # 3. a manager restore recovers past it to the last known good
    mgr = CheckpointManager(str(tmp_path), synchronous=True)
    step, tree, _ = mgr.restore_latest()
    assert step == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])
    mgr.close()


def test_validation_failures_counted_and_reported(tmp_path):
    from repro.core.clocks import counter_channel
    from repro.core.timers import timer_db

    _, newest = _two_checkpoints(tmp_path)
    drop_commit(newest)
    before = counter_channel("ckpt_validation_failures")
    plan_resume(str(tmp_path), quarantine=True)
    assert counter_channel("ckpt_validation_failures") == before + 1
    # the quarantine reason lands as a CHECKPOINT/ count row in the timer DB
    assert timer_db().exists("CHECKPOINT/quarantine::missing_commit")


def test_stale_tmp_debris_quarantined(tmp_path):
    """A SIGKILLed writer can only leave a ``.tmp`` directory; the scanner
    must classify it as ``stale_tmp`` and the resume sweep it aside."""
    _two_checkpoints(tmp_path)
    debris = simulate_writer_kill(str(tmp_path), 3, rng=seeded_rng(3))
    records = scan_checkpoints(str(tmp_path))
    assert {r.reason for r in records if r.status != "valid"} == {"stale_tmp"}
    plan = plan_resume(str(tmp_path), quarantine=True)
    assert plan.selected.step == 2
    assert not os.path.exists(debris)
    assert os.path.isdir(os.path.join(str(tmp_path), "corrupt"))


def test_every_plan_kind_dispatches(tmp_path):
    """``apply_checkpoint_event`` covers the whole matrix: each kind leaves
    the target either invalid or (kill_writer) with stale debris."""
    for kind in CHECKPOINT_FAULTS:
        root = tmp_path / kind
        root.mkdir()
        path, _ = save_checkpoint(str(root), 1, _tree())
        event = FaultEvent(step=0, kind=kind, target=0)
        touched = apply_checkpoint_event(event, path, rng=seeded_rng(kind))
        if kind == "kill_writer":
            assert touched.endswith(".tmp") and os.path.isdir(touched)
            validate_checkpoint(path)  # original untouched
        else:
            with pytest.raises(CheckpointCorrupt):
                validate_checkpoint(path)


def test_fault_plan_deterministic():
    a = FaultPlan.random(11, 500, hosts=(0, 1, 2))
    b = FaultPlan.random(11, 500, hosts=(0, 1, 2))
    assert a.events == b.events
    assert len(a.events) > 0
    # per-event RNG replays identically and independently of plan order
    event = a.events[0]
    assert a.rng_for(event).random() == b.rng_for(event).random()
    c = FaultPlan.random(12, 500, hosts=(0, 1, 2))
    assert c.events != a.events


def test_fleet_faults_roundtrip():
    from repro.adapt.fleet import SimulatedFleet
    from repro.faults import apply_fleet_event

    fleet = SimulatedFleet(2, 4)
    nominal = dict(fleet.costs)
    apply_fleet_event(FaultEvent(step=0, kind="hang_host", target=1), fleet)
    assert fleet.costs[1] == nominal[1] * 1000.0
    apply_fleet_event(FaultEvent(step=1, kind="slow_host", target=0, arg=3.0), fleet)
    assert fleet.costs[0] == nominal[0] * 3.0
    apply_fleet_event(FaultEvent(step=2, kind="restore_host", target=0), fleet)
    apply_fleet_event(FaultEvent(step=2, kind="restore_host", target=1), fleet)
    assert fleet.costs == nominal


def test_bitflip_deterministic_from_seed(tmp_path):
    """Same seed, same flip: a failing soak replays byte-for-byte."""
    flips = []
    for name in ("a", "b"):
        root = tmp_path / name
        root.mkdir()
        path, _ = save_checkpoint(str(root), 1, _tree())
        bit_flip_leaf(path, rng=seeded_rng(99))
        with open(os.path.join(path, "leaf_00000.npy"), "rb") as f:
            flips.append(f.read())
    assert flips[0] == flips[1]
