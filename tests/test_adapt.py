"""The repro.adapt control plane: ControlLoop dispatch + ADAPT/ recording,
AdaptCheck as a controller, straggler response (rebalance -> evict -> mesh
rebuild) over a simulated CPU fleet, and the supporting dist primitives
(LocalTransport, MicrobatchPlan, detector eviction, remove_host validation)."""

import pytest

from repro.adapt import (
    CheckpointControl,
    ControlAction,
    ControlLoop,
    Measurement,
    SimulatedFleet,
    StragglerResponse,
)
from repro.core import adapt_rows, format_adapt_report, format_report
from repro.core.adaptive import AdaptiveCheckpointPolicy
from repro.core.schedule import RunState, Scheduler
from repro.core.timers import TimerDB
from repro.dist.meshutil import local_mesh, remove_host
from repro.dist.pipeline import MicrobatchPlan
from repro.dist.stragglers import LocalTransport, StragglerDetector


# ---------------------------------------------------------------------------
# ControlLoop core
# ---------------------------------------------------------------------------


class _Bumper:
    """Minimal controller: acts whenever its polled channel has windows."""

    def __init__(self, channel="EVOL/step"):
        self.name = "bumper"
        self.channels = (channel,)
        self.seen = []

    def control(self, step, measurements):
        m = measurements[self.channels[0]]
        self.seen.append((step, m))
        if m.count == 0:
            return []
        return [
            ControlAction(
                step=step, controller=self.name, trigger=self.channels[0],
                action="bump", detail={"count": m.count},
            )
        ]


def test_control_loop_polls_channels_and_records_actions():
    db = TimerDB()
    loop = ControlLoop(db)
    ctrl = loop.register(_Bumper())

    # channel missing: measured as zero, no action
    assert loop.poll(0) == []
    assert ctrl.seen[0] == (0, Measurement(0.0, 0))

    h = db.create("EVOL/step")
    db.start(h)
    db.stop(h)
    actions = loop.poll(1)
    assert len(actions) == 1 and actions[0].action == "bump"
    assert ctrl.seen[1][1].count == 1
    # decision log + published aggregate row
    assert loop.actions == actions
    assert db.exists("ADAPT/bumper::bump")
    assert db.get("ADAPT/bumper::bump").count == 1
    assert loop.summary()["action_counts"] == {"bumper::bump": 1}


def test_control_loop_registry_rules():
    loop = ControlLoop(TimerDB())
    loop.register(_Bumper())
    with pytest.raises(ValueError):
        loop.register(_Bumper())  # duplicate name
    assert loop.controllers() == ["bumper"]
    loop.unregister("bumper")
    with pytest.raises(ValueError):
        loop.unregister("bumper")


def test_scheduler_attaches_control_loop_with_auto_timer():
    db = TimerDB()
    sch = Scheduler(db)
    loop = ControlLoop(db)
    polled = []
    loop.register(
        type(
            "Recorder",
            (),
            {
                "name": "rec",
                "channels": (),
                "control": lambda self, step, m: polled.append(step) or [],
            },
        )()
    )
    sch.attach_control_loop(loop)
    sch.run(RunState(max_iterations=3))
    assert polled == [0, 1, 2]
    # the loop poll is caliper-timed like any other routine
    assert db.exists("ANALYSIS/adapt::control_loop")
    assert db.get("ANALYSIS/adapt::control_loop").count == 3


def test_adapt_report_sections():
    db = TimerDB()
    loop = ControlLoop(db)
    loop.register(_Bumper())
    h = db.create("EVOL/step")
    db.start(h)
    db.stop(h)
    loop.poll(4)
    rows = adapt_rows(loop)
    assert rows == [
        {"step": 4, "controller": "bumper", "action": "bump",
         "trigger": "EVOL/step", "detail": {"count": 1}}
    ]
    text = format_report(db, adapt=loop)
    assert "ADAPT/bumper::bump" in text          # aggregate count row
    assert "ADAPT decisions (1)" in text         # decision-log section
    assert "bump" in format_adapt_report(loop)
    empty = format_adapt_report(ControlLoop(TimerDB()))
    assert "no adaptation decisions" in empty


# ---------------------------------------------------------------------------
# CheckpointControl (AdaptCheck on the registry)
# ---------------------------------------------------------------------------


def _fake_clock():
    state = {"t": 0.0}

    def clock():
        return state["t"]

    clock.state = state
    return clock


def test_checkpoint_control_admits_and_records():
    db = TimerDB()
    clock = _fake_clock()
    ctrl = CheckpointControl(
        AdaptiveCheckpointPolicy(mode="adaptive", max_fraction=0.5),
        ckpt_timer="CHECKPOINT/write",
        clock=clock,
    )
    ctrl.start_run(0.0)
    loop = ControlLoop(db)
    loop.register(ctrl)

    clock.state["t"] = 10.0
    actions = loop.poll(1)
    # no history, fraction 0 -> weak bound admits
    assert [a.action for a in actions] == ["checkpoint"]
    assert actions[0].detail["reason"] == "under-bound"
    decision = ctrl.take_decision()
    assert decision is not None and decision.checkpoint
    assert ctrl.take_decision() is None  # consumed

    ctrl.observe_checkpoint(seconds=9.0, nbytes=100.0)
    # now 9s of 10.1s total is checkpointing: way over the 0.5 bound
    clock.state["t"] = 10.1
    db.get(db.create("CHECKPOINT/write")).set_channel("walltime", 9.0)
    assert loop.poll(2) == []
    suppressed = ctrl.take_decision()
    assert suppressed is not None and not suppressed.checkpoint
    assert ctrl.summary()["n_suppressed"] == 1


def test_checkpoint_control_live_steering_via_registry():
    from repro.core.params import ParamRegistry

    reg = ParamRegistry()
    reg.declare("ckpt.max_fraction", 0.05, steerable=True)
    reg.declare("ckpt.max_interval_s", 1e9, steerable=True)
    clock = _fake_clock()
    ctrl = CheckpointControl(
        AdaptiveCheckpointPolicy(mode="adaptive", max_fraction=0.05,
                                 max_interval_seconds=1e9),
        clock=clock,
        registry=reg,
    )
    ctrl.start_run(0.0)
    reg.set("ckpt.max_fraction", 0.75)
    clock.state["t"] = 1.0
    ctrl.control(1, {ctrl.ckpt_timer: Measurement(0.0, 0)})
    assert ctrl.inner.policy.max_fraction == 0.75  # steered value took effect


# ---------------------------------------------------------------------------
# The acceptance scenario: simulated fleet, straggler on host k
# ---------------------------------------------------------------------------


def test_fleet_rebalance_reduces_spread_then_eviction_recovers():
    """Straggler injected on host 2: the control loop first shifts microbatches
    away from it (spread drops measurably), the host degrades further, and the
    loop evicts it and rebuilds the mesh (spread recovers to ~zero)."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 16, db=db, window=2, threshold=1.3, check_every=1,
        confirm_after=1, evict_after=6, min_weight=0.2,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)

    baseline_share = fleet.plan.shares()[2]
    assert baseline_share == 4

    # phase 1: mild (2x) slowdown -> rebalance suffices
    fleet.slow_host(2, 2.0)
    fleet.run_step(0)
    spread_before = fleet.spread()
    for step in range(6):
        if step:
            fleet.run_step(step)
        loop.poll(step)
    fleet.run_step(6)
    spread_after_rebalance = fleet.spread()

    rebalances = [a for a in loop.actions if a.action == "rebalance"]
    assert rebalances and rebalances[0].detail["host"] == 2
    assert fleet.plan.shares()[2] < baseline_share      # share shrank
    assert 2 in fleet.active_hosts()                    # still in the fleet
    assert spread_after_rebalance <= 0.5 * spread_before  # measurably better

    # phase 2: the host degrades badly -> weight floor -> eviction
    fleet.slow_host(2, 8.0)
    step = 7
    degraded_spread = 0.0
    while 2 in fleet.active_hosts() and step < 20:
        fleet.run_step(step)
        degraded_spread = max(degraded_spread, fleet.spread())
        loop.poll(step)
        step += 1

    evictions = [a for a in loop.actions if a.action == "evict"]
    assert len(evictions) == 1 and evictions[0].detail["host"] == 2
    assert fleet.active_hosts() == [0, 1, 3]
    assert fleet.mesh_generation == 1                   # mesh was rebuilt
    assert set(fleet.meshes) == {0, 1, 3}
    assert sum(fleet.plan.shares().values()) == 16      # work fully re-apportioned

    # recovery: homogeneous survivors -> spread collapses to the one-microbatch
    # apportionment granularity (16 over 3 hosts cannot split exactly evenly)
    fleet.run_step(step)
    granularity = max(fleet.costs.values())
    assert fleet.spread() <= granularity + 1e-9
    assert fleet.spread() < 0.1 * degraded_spread

    # every decision visible as ADAPT/ rows: weight changes, then the evict
    rows = adapt_rows(loop)
    assert rows and rows[-1]["action"] == "evict"
    assert all(r["action"] in ("rebalance", "restore") for r in rows[:-1])
    assert any(r["action"] == "rebalance" for r in rows)
    assert all(r["trigger"] == "DIST/host2::step" for r in rows)
    text = format_report(db, adapt=loop)
    assert "ADAPT/stragglers::rebalance" in text
    assert "ADAPT/stragglers::evict" in text
    # fleet-health rows tag the evicted host
    from repro.core import straggler_rows

    tagged = [r["timer"] for r in straggler_rows(fleet.detector)]
    assert any("host2::step [EVICTED]" in t for t in tagged)


def test_fleet_runs_real_pipeline_with_rebalanced_shares():
    """run_pipeline=True actually pushes each host's share through
    gpipe_forward on its local mesh, before and after a rebalance."""
    db = TimerDB()
    fleet = SimulatedFleet(
        3, 9, db=db, window=2, threshold=1.3, check_every=1,
        min_weight=0.2, run_pipeline=True,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(1, 2.0)
    for step in range(3):
        fleet.run_step(step)   # raises inside if any pipeline call breaks
        loop.poll(step)
    assert fleet.plan.shares()[1] < 3


def test_rebalanced_host_judged_on_fresh_samples_not_evicted():
    """Regression: a correctly rebalanced host must not be re-derated and
    evicted off window samples measured under its *old* (larger) share."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 16, db=db, window=4, threshold=1.2, check_every=1,
        confirm_after=1, evict_after=4, min_weight=0.4,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(2, 2.0)  # ideal weight 0.5, comfortably above the floor
    for step in range(16):
        fleet.run_step(step)
        loop.poll(step)
    assert 2 in fleet.active_hosts()            # never evicted
    assert not [a for a in loop.actions if a.action == "evict"]
    assert abs(fleet.plan.weights[2] - 0.5) < 0.15  # settled near the ideal
    # and the fleet is balanced: host 2's step time sits at the median
    seconds = fleet.run_step(16)
    median = sorted(seconds.values())[len(seconds) // 2]
    assert seconds[2] <= 1.2 * median


def test_transient_slowdown_recovers_full_weight():
    """A derated host whose slowdown clears earns its share back (restore
    actions), so one hiccup never permanently costs fleet capacity."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 16, db=db, window=2, threshold=1.3, check_every=1,
        confirm_after=1, evict_after=8, min_weight=0.25,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(1, 3.0)
    for step in range(5):
        fleet.run_step(step)
        loop.poll(step)
    derated = fleet.plan.weights[1]
    assert derated < 0.5 and fleet.plan.shares()[1] < 4
    fleet.slow_host(1, 1 / 3.0)  # the slowdown clears
    for step in range(5, 20):
        fleet.run_step(step)
        loop.poll(step)
    assert [a for a in loop.actions if a.action == "restore"]
    # weight climbs back until the share is restored (hysteresis stops the
    # last few percent once the host already holds its full share)
    assert fleet.plan.weights[1] > 0.8
    assert fleet.plan.shares()[1] == 4          # share back to the equal split
    assert 1 in fleet.active_hosts()


def test_granularity_blocked_straggler_hits_evict_backstop():
    """When share granularity cannot absorb a slow host (it is down to the
    1-microbatch minimum and still far off the fleet), the evict_after streak
    backstop must still fire — the fleet must not run degraded forever."""
    transport = LocalTransport()
    det = StragglerDetector(2, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(2), 4)  # tiny fleet: shares {2, 2}
    resp = StragglerResponse(det, plan, confirm_after=1, evict_after=4,
                             min_weight=0.25)
    evicted = None
    for step in range(14):
        shares = plan.shares()
        for h in plan.hosts:
            transport.publish(h, (6.0 if h == 0 else 1.0) * shares[h])
        for a in resp.control(step, {}):
            if a.action == "evict":
                evicted = a
    assert evicted is not None and evicted.detail["host"] == 0
    assert plan.hosts == [1]


def test_two_simultaneous_stragglers_both_rebalanced_same_check():
    """Acting on the first straggler shifts live shares; the second must
    still be judged against the shares its samples were measured under."""
    transport = LocalTransport()
    det = StragglerDetector(6, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(6), 24)
    resp = StragglerResponse(det, plan, confirm_after=1, evict_after=8,
                             min_weight=0.25)
    costs = {h: (3.0 if h in (1, 4) else 1.0) for h in range(6)}
    for h in plan.hosts:
        transport.publish(h, costs[h] * plan.shares()[h])
    actions = resp.control(0, {})
    assert sorted(a.detail["host"] for a in actions) == [1, 4]
    assert all(a.action == "rebalance" for a in actions)
    shares = plan.shares()
    assert shares[1] < 4 and shares[4] < 4  # both derated in one check


def test_rounding_extra_microbatch_shed_instead_of_eviction():
    """A derated host whose only residual imbalance is one rounding-parked
    microbatch sheds it (rebalance) rather than being escalated to eviction."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 16, db=db, window=4, threshold=1.2, check_every=1,
        confirm_after=1, evict_after=4, min_weight=0.4,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(2, 2.0)
    for step in range(16):
        fleet.run_step(step)
        loop.poll(step)
    assert 2 in fleet.active_hosts()
    assert not [a for a in loop.actions if a.action == "evict"]
    # and the policy settles instead of ping-ponging shed <-> restore
    assert not [a for a in loop.actions if a.step >= 10]


def test_restore_returns_to_original_above_one_weight():
    """A host provisioned with weight > 1.0 (bigger machine) recovers to its
    ORIGINAL weight after a transient slowdown, not to the 1.0 default."""
    transport = LocalTransport()
    det = StragglerDetector(3, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan(n_micro=16, weights={0: 2.0, 1: 1.0, 2: 1.0})
    resp = StragglerResponse(det, plan, confirm_after=1, evict_after=8,
                             min_weight=0.25)

    def run_checks(costs, start, n):
        for step in range(start, start + n):
            shares = plan.shares()
            for h in plan.hosts:
                transport.publish(h, costs[h] * shares[h])
            resp.control(step, {})

    run_checks({0: 2.0, 1: 1.0, 2: 1.0}, 0, 2)       # host 0 transiently 2x slow
    assert plan.weights[0] < 2.0                      # derated
    assert 0 in plan.weights                          # but not evicted
    run_checks({0: 1.0, 1: 1.0, 2: 1.0}, 2, 20)      # slowdown clears
    assert plan.weights[0] > 1.5                      # climbed past the 1.0 cap
    assert plan.shares()[0] == 8                      # original double share back


def test_straggler_response_confirmation_and_hysteresis():
    """One flagged window is not acted on before confirm_after; sub-tolerance
    weight changes are suppressed."""
    transport = LocalTransport()
    det = StragglerDetector(3, window=4, threshold=1.5, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(3), 9)
    resp = StragglerResponse(det, plan, confirm_after=2, evict_after=4,
                             min_weight=0.1)
    for h in range(3):
        transport.publish(h, 4.0 if h == 1 else 1.0)
    assert resp.control(0, {}) == []          # flagged once: unconfirmed
    assert plan.shares()[1] == 3
    for h in range(3):
        transport.publish(h, 4.0 if h == 1 else 1.0)
    actions = resp.control(1, {})             # flagged twice: act
    assert [a.action for a in actions] == ["rebalance"]
    assert plan.shares()[1] < 3


# ---------------------------------------------------------------------------
# Stage-depth rebalancing (restage): the 1F1B acceptance scenario
# ---------------------------------------------------------------------------


def test_pipeline_fleet_restage_moves_stage_boundary():
    """A straggler that owns a pipeline stage is answered by moving the stage
    boundary: the ADAPT log records a ``restage``, the slow host's depth
    shrinks, its step time drops, and the restaged (uneven) boundaries really
    execute through a 1F1B pipeline_step (run_pipeline=True)."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 8, db=db, window=2, threshold=1.3, check_every=1,
        confirm_after=1, evict_after=8, n_layers=12, run_pipeline=True,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    assert fleet.stage_plan.depths() == {0: 3, 1: 3, 2: 3, 3: 3}

    fleet.slow_host(2, 2.5)
    fleet.run_step(0)
    seconds_before = fleet.last_step_seconds[2]
    for step in range(8):
        if step:
            fleet.run_step(step)
        loop.poll(step)
    fleet.run_step(8)

    restages = [a for a in loop.actions if a.action == "restage"]
    assert restages and restages[0].detail["host"] == 2
    assert restages[0].detail["stage"] == 2
    # the boundary move is preferred: no share derate before the restage
    first_action = adapt_rows(loop)[0]
    assert first_action["action"] == "restage"
    depths = fleet.stage_plan.depths()
    assert depths[2] < 3 and sum(depths.values()) == 12
    assert min(depths.values()) >= 1
    assert fleet.restages and fleet.restages[0][:2] == (2, 2)
    assert fleet.last_step_seconds[2] < seconds_before  # work really moved
    assert 2 in fleet.active_hosts()                    # moved, not evicted
    # the decision is visible as an ADAPT/ row in the timer report
    assert db.exists("ADAPT/stragglers::restage")
    assert "ADAPT/stragglers::restage" in format_report(db, adapt=loop)


def test_restage_granularity_exhausted_escalates_to_evict_backstop():
    """When every stage is already at one layer the boundary cannot move, and
    a share derate would shed no work for a stage owner (its stage runs every
    microbatch regardless) — so escalation goes straight to the evict_after
    backstop: no restage, no rebalance, eventually an eviction."""
    from repro.dist.pipeline import StagePlan

    transport = LocalTransport()
    det = StragglerDetector(3, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(3), 9)
    stage_plan = StagePlan.equal(range(3), 3)  # depths {1, 1, 1}: immovable
    resp = StragglerResponse(
        det, plan, confirm_after=1, evict_after=4, min_weight=0.25,
        stage_plan=stage_plan, stage_for_host={h: h for h in range(3)},
    )
    actions = []
    n_micro = plan.n_micro
    for step in range(8):
        depths = stage_plan.depths()
        for h in plan.hosts:
            stage = resp.stage_for_host.get(h)
            work = n_micro * depths[stage] if stage in depths else plan.shares()[h]
            transport.publish(h, (3.0 if h == 1 else 1.0) * work)
        actions += resp.control(step, {})
    kinds = [a.action for a in actions]
    assert "restage" not in kinds and "rebalance" not in kinds
    assert kinds.count("evict") == 1
    assert plan.hosts == [0, 2]
    assert set(stage_plan.weights) == {0, 2}  # evicted owner's stage dropped


def test_deliberately_deeper_stage_owner_not_misjudged():
    """Per-unit slowdown normalizes by share x stage depth: a host owning a
    deliberately deeper stage takes proportionally longer steps by design and
    must trigger no action."""
    from repro.dist.pipeline import StagePlan

    transport = LocalTransport()
    det = StragglerDetector(2, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(2), 4)           # shares {2, 2}
    stage_plan = StagePlan(n_layers=4, weights={0: 3.0, 1: 1.0})  # depths {3, 1}
    resp = StragglerResponse(
        det, plan, confirm_after=1, evict_after=8, min_weight=0.25,
        stage_plan=stage_plan, stage_for_host={0: 0, 1: 1},
    )
    for step in range(6):
        depths = stage_plan.depths()
        for h in plan.hosts:
            # identical per-unit speed; raw time scales with share x depth
            transport.publish(h, 1.0 * plan.shares()[h] * depths[h])
        assert resp.control(step, {}) == []
    assert stage_plan.depths() == {0: 3, 1: 1}
    assert plan.shares() == {0: 2, 1: 2}


def test_transient_stage_slowdown_restores_layers():
    """The stage-side restore mirror: a restaged host whose throttle clears
    earns its layers back (restore action on the stage plan), so a transient
    hiccup never permanently parks layers on the healthy stages."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 8, db=db, window=2, threshold=1.3, check_every=1,
        confirm_after=1, evict_after=10, n_layers=12,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(2, 2.5)
    for step in range(6):
        fleet.run_step(step)
        loop.poll(step)
    assert fleet.stage_plan.depths()[2] < 3        # restaged down
    fleet.slow_host(2, 1 / 2.5)                    # the throttle clears
    for step in range(6, 20):
        fleet.run_step(step)
        loop.poll(step)
    restores = [a for a in loop.actions if a.action == "restore"]
    assert restores and restores[0].detail["host"] == 2
    assert fleet.stage_plan.depths() == {0: 3, 1: 3, 2: 3, 3: 3}  # layers back
    # and the recovered boundaries were re-packed by the fleet actuator
    assert any(r[0] == 2 and r[2][2] == 3 for r in fleet.restages)


def test_pipeline_fleet_unequal_shares_only_real_straggler_acted_on():
    """Stage owners are normalized by n_micro x depth (share-independent) and
    their microbatch weight is never derated or restored: with an unequal
    share distribution, the only host acted on is the genuinely slow one."""
    db = TimerDB()
    fleet = SimulatedFleet(
        4, 8, db=db, window=2, threshold=1.3, check_every=1,
        confirm_after=1, evict_after=8, n_layers=12,
    )
    fleet.plan.set_weight(3, 0.4)   # healthy host with a small share
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    fleet.slow_host(2, 2.5)
    for step in range(8):
        fleet.run_step(step)
        loop.poll(step)
    rows = adapt_rows(loop)
    assert rows and {r["detail"]["host"] for r in rows} == {2}
    assert all(r["action"] == "restage" for r in rows)
    assert fleet.stage_plan.depths()[2] < 3


def test_restage_only_succeeds_when_stragglers_own_stage_sheds():
    """Regression: derating a stage weight can shuffle a layer between two
    *healthy* stages through largest-remainder rounding while the slow stage
    keeps its full depth — that must not count as a restage (no boundary
    churn, no streak reset); the streak keeps growing toward the evict
    backstop instead."""
    from repro.dist.pipeline import StagePlan

    transport = LocalTransport()
    det = StragglerDetector(3, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(3), 6)
    # the reviewer-found weight set: derating stage 1 moves a layer from
    # stage 0 to stage 2, never off stage 1 itself
    stage_plan = StagePlan(n_layers=11, weights={0: 0.34, 1: 2.14, 2: 2.73})
    depths_before = stage_plan.depths()
    assert depths_before == {0: 2, 1: 4, 2: 5}
    resp = StragglerResponse(
        det, plan, confirm_after=1, evict_after=10, min_weight=0.25,
        stage_plan=stage_plan, stage_for_host={0: 0, 1: 1, 2: 2},
    )
    actions = []
    for step in range(3):
        depths = stage_plan.depths()
        for h in plan.hosts:
            transport.publish(
                h, (3.0 if h == 1 else 1.0) * plan.shares()[h] * depths[h]
            )
        actions += resp.control(step, {})
    restages = [a for a in actions if a.action == "restage"]
    # every logged restage must have really shed a layer off stage 1; stage
    # owners never get a share derate, so no rebalance can appear either way
    for a in restages:
        assert a.detail["depths"][1] < depths_before[1]
    assert not [a for a in actions if a.action == "rebalance"]


def test_evicting_stage_owner_drops_its_stage_from_the_plan():
    """Regression: an evicted host's stage must leave the StagePlan (its
    layers re-apportion among survivors) — depths() must never keep
    assigning layers to a rank nobody runs."""
    from repro.dist.pipeline import StagePlan

    transport = LocalTransport()
    det = StragglerDetector(3, window=2, threshold=1.3, transport=transport,
                            publish=False)
    plan = MicrobatchPlan.equal(range(3), 6)
    stage_plan = StagePlan.equal(range(3), 3)  # depth 1 each: restage blocked
    resp = StragglerResponse(
        det, plan, confirm_after=1, evict_after=3, min_weight=0.25,
        stage_plan=stage_plan, stage_for_host={h: h for h in range(3)},
    )
    evicted = []
    for step in range(12):
        shares = plan.shares()
        for h in plan.hosts:
            transport.publish(h, (8.0 if h == 1 else 1.0) * shares[h])
        evicted += [a for a in resp.control(step, {}) if a.action == "evict"]
    assert evicted and evicted[0].detail["host"] == 1
    assert plan.hosts == [0, 2]
    assert set(stage_plan.weights) == {0, 2}          # stage 1 gone
    depths = stage_plan.depths()
    assert sum(depths.values()) == 3                  # layers re-apportioned
    assert 1 not in resp.stage_for_host


def test_stage_plan_and_host_map_must_come_together():
    from repro.dist.pipeline import StagePlan

    det = StragglerDetector(2, window=2, threshold=1.3, publish=False)
    plan = MicrobatchPlan.equal(range(2), 4)
    with pytest.raises(ValueError):
        StragglerResponse(det, plan, stage_plan=StagePlan.equal(range(2), 4))
    with pytest.raises(ValueError):
        StragglerResponse(det, plan, stage_for_host={0: 0})


# ---------------------------------------------------------------------------
# dist primitives backing the controller
# ---------------------------------------------------------------------------


def test_local_transport_gather_drains_and_drops():
    t = LocalTransport()
    t.publish(0, 1.0)
    t.publish(1, 2.0)
    t.publish(1, 3.0)
    assert t.gather() == {0: [1.0], 1: [2.0, 3.0]}
    assert t.gather() == {}
    t.drop_host(1)
    t.publish(1, 4.0)
    assert t.gather() == {}
    assert t.dropped == frozenset({1})


def test_detector_eviction_semantics():
    det = StragglerDetector(3, window=4, threshold=1.5, publish=False)
    for _ in range(4):
        for h in range(3):
            det.observe(h, 3.0 if h == 0 else 1.0)
    assert det.check(0).stragglers == [0]
    det.evict(0)
    det.observe(0, 9.0)  # late sample from the evicted host: dropped
    report = det.check(1)
    assert report.stragglers == [] and 0 not in report.host_means
    assert det.active_hosts() == [1, 2]
    assert 0 in det.host_stats()  # history survives for the report
    with pytest.raises(ValueError):
        det.evict(7)
    det.evict(1)
    with pytest.raises(ValueError):
        det.evict(2)  # cannot evict the last active host


def test_microbatch_plan_validation_and_shares():
    plan = MicrobatchPlan.equal(range(4), 16)
    assert plan.shares() == {0: 4, 1: 4, 2: 4, 3: 4}
    plan.set_weight(2, 0.5)
    shares = plan.shares()
    assert sum(shares.values()) == 16 and shares[2] < 4
    assert min(shares.values()) >= 1
    plan.evict(2)
    assert sum(plan.shares().values()) == 16
    with pytest.raises(ValueError):
        plan.set_weight(9, 1.0)
    with pytest.raises(ValueError):
        plan.set_weight(0, 0.0)
    with pytest.raises(ValueError):
        MicrobatchPlan.equal(range(5), 4)  # fewer microbatches than hosts
    solo = MicrobatchPlan.equal([0], 4)
    with pytest.raises(ValueError):
        solo.evict(0)  # cannot evict the last host


def test_remove_host_validation_on_local_mesh():
    mesh = local_mesh((1, 1))
    with pytest.raises(ValueError):
        remove_host(mesh, 0)            # size-1 axis cannot lose its slice
    with pytest.raises(ValueError):
        remove_host(mesh, 0, axis="nope")


# ---------------------------------------------------------------------------
# Real-device mesh rebuild (forced multi-device subprocess, nightly tier)
# ---------------------------------------------------------------------------

REMOVE_HOST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.meshutil import local_mesh, remove_host

mesh = local_mesh((8,), ("data",))
assert mesh.shape["data"] == 8

# evict host 3: axis shrinks, survivors keep their order
small = remove_host(mesh, 3, axis="data")
assert small.shape["data"] == 7
kept = [d.id for d in small.devices.flat]
assert kept == [0, 1, 2, 4, 5, 6, 7], kept

# the rebuilt mesh computes: a psum over the surviving axis
f = shard_map(
    lambda x: jax.lax.psum(x, "data"),
    mesh=small, in_specs=P("data"), out_specs=P(),
)
out = f(jnp.ones((7, 2)))
assert out.shape == (1, 2) and float(out[0, 0]) == 7.0, (out.shape, out)

# a multi-axis mesh shrinks along the named axis only
grid = local_mesh((4, 2), ("data", "model"))
shrunk = remove_host(grid, 1, axis="data")
assert dict(shrunk.shape) == {"data": 3, "model": 2}
print("REMOVE_HOST_OK")
"""


@pytest.mark.multihost
@pytest.mark.slow
def test_remove_host_on_real_devices_subprocess():
    """Eviction rebuild on a real (forced) 8-device topology: slice removed,
    device order preserved, collectives run on the shrunk mesh."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", REMOVE_HOST_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env, cwd=repo,
    )
    assert proc.returncode == 0, proc.stderr
    assert "REMOVE_HOST_OK" in proc.stdout


# ---------------------------------------------------------------------------
# checkpoint-before-evict barrier
# ---------------------------------------------------------------------------


def _drive_to_eviction(fleet, loop, host, max_steps=40):
    fleet.slow_host(host, 50.0)
    step = 0
    while host in fleet.active_hosts() and step < max_steps:
        fleet.run_step(step)
        loop.poll(step)
        step += 1
    return step


def test_checkpoint_before_evict_barrier_precedes_eviction(tmp_path):
    """An eviction gated by CheckpointControl.evict_barrier performs a durable
    save first, and the ADAPT/ log shows the checkpoint::before_evict row
    immediately before the stragglers::evict row."""
    from repro.checkpoint import CheckpointManager

    db = TimerDB()
    fleet = SimulatedFleet(
        3, 9, db=db, window=2, threshold=1.3, confirm_after=1,
        evict_after=3, min_weight=0.5,
    )
    manager = CheckpointManager(str(tmp_path), synchronous=True)
    ctrl = CheckpointControl(AdaptiveCheckpointPolicy(mode="adaptive"))
    ctrl.start_run(0.0)

    def durable_save(step):
        manager.save(step, {"w": [float(step)]})
        manager.wait()
        return 0.01

    ctrl.bind_durable_save(durable_save)
    fleet.controller.evict_barrier = ctrl.evict_barrier
    loop = ControlLoop(db)
    loop.register(fleet.controller)

    _drive_to_eviction(fleet, loop, 2)

    assert 2 not in fleet.active_hosts()
    kinds = [(a.controller, a.action) for a in loop.actions]
    evict_at = kinds.index(("stragglers", "evict"))
    assert kinds[evict_at - 1] == ("checkpoint", "before_evict")
    # the save is really on disk, durable, before the eviction committed
    assert manager.checkpoints(), "barrier save never landed"
    assert ctrl.barrier_saves == 1
    # visible in the rendered ADAPT/ report like every other adaptation
    assert "ADAPT/checkpoint::before_evict" in format_report(db, adapt=loop)
    manager.close()


def test_failed_barrier_defers_eviction_until_save_succeeds():
    """A failing durable save vetoes the eviction (the fleet must not shrink
    without a safety checkpoint); once the save path recovers, the still-
    growing streak retries and the eviction proceeds."""
    db = TimerDB()
    fleet = SimulatedFleet(
        3, 9, db=db, window=2, threshold=1.3, confirm_after=1,
        evict_after=3, min_weight=0.5,
    )
    ctrl = CheckpointControl(AdaptiveCheckpointPolicy(mode="adaptive"))
    ctrl.start_run(0.0)
    ctrl.bind_durable_save(lambda step: (_ for _ in ()).throw(OSError("disk full")))
    fleet.controller.evict_barrier = ctrl.evict_barrier
    loop = ControlLoop(db)
    loop.register(fleet.controller)

    step = _drive_to_eviction(fleet, loop, 2, max_steps=12)

    assert 2 in fleet.active_hosts(), "eviction must be deferred while saves fail"
    assert fleet.controller.deferred_evictions >= 1
    assert ctrl.barrier_failures >= 1
    assert not [a for a in loop.actions if a.action == "evict"]

    # the save path recovers -> the next flagged check evicts
    ctrl.bind_durable_save(lambda s: 0.01)
    while 2 in fleet.active_hosts() and step < 30:
        fleet.run_step(step)
        loop.poll(step)
        step += 1
    assert 2 not in fleet.active_hosts()
    kinds = [(a.controller, a.action) for a in loop.actions]
    assert ("checkpoint", "before_evict") in kinds
    assert kinds.index(("checkpoint", "before_evict")) + 1 == kinds.index(
        ("stragglers", "evict")
    )


def test_unbarriered_response_keeps_prior_semantics():
    """No evict_barrier (the default) -> eviction behaves exactly as before."""
    db = TimerDB()
    fleet = SimulatedFleet(
        3, 9, db=db, window=2, threshold=1.3, confirm_after=1,
        evict_after=3, min_weight=0.5,
    )
    loop = ControlLoop(db)
    loop.register(fleet.controller)
    _drive_to_eviction(fleet, loop, 2)
    assert 2 not in fleet.active_hosts()
    assert fleet.controller.deferred_evictions == 0
    assert not [a for a in loop.actions if a.controller == "checkpoint"]
