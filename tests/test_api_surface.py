"""Public-API snapshot of the ``repro.timing`` facade.

The facade is the repo's supported instrumentation surface; future PRs must
not silently rename, drop, or re-sign it.  Changing anything below is an API
decision — update this snapshot *and* the README migration table together.
"""

import inspect

import repro.timing as timing

EXPECTED_ALL = [
    "ScopeHandle",
    "Timer",
    "TimerDB",
    "TimerNode",
    "TimingSession",
    "counter",
    "current_scope",
    "current_session",
    "format_tree",
    "scope",
    "scope_handle",
    "session",
    "timed",
    "timer_db",
    "total_seconds",
    "tree",
]

# facade callables: exact parameter names, in order
EXPECTED_PARAMS = {
    "scope": ["name", "db"],
    "scope_handle": ["path", "db"],
    "current_scope": ["db"],
    "counter": ["name", "absolute", "db"],
    "timed": ["name", "db"],
    "session": ["db", "kwargs"],
    "current_session": [],
    "tree": ["db"],
    "format_tree": ["db", "prefix", "title"],
    "total_seconds": ["prefix", "db"],
    "timer_db": [],
}

EXPECTED_SESSION_METHODS = {
    "scope": ["self", "name"],
    "scope_handle": ["self", "path"],
    "counter": ["self", "name", "absolute"],
    "timer": ["self", "ref"],
    "tree": ["self"],
    "tree_rows": ["self", "prefix"],
    "total_seconds": ["self", "prefix"],
    "report": ["self", "kwargs"],
    "tree_report": ["self", "kwargs"],
    "snapshot": ["self"],
    "__enter__": ["self"],
    "__exit__": ["self", "exc_type", "exc", "tb"],
}


def test_all_is_frozen():
    assert list(timing.__all__) == EXPECTED_ALL


def test_every_name_importable():
    for name in timing.__all__:
        assert getattr(timing, name, None) is not None, name


def test_facade_signatures():
    for name, params in EXPECTED_PARAMS.items():
        sig = inspect.signature(getattr(timing, name))
        assert list(sig.parameters) == params, f"{name}{sig}"


def test_session_constructor_signature():
    sig = inspect.signature(timing.TimingSession.__init__)
    assert list(sig.parameters) == ["self", "db", "scheduler", "control_loop"]
    # scheduler/control_loop are keyword-only injection points
    assert sig.parameters["scheduler"].kind is inspect.Parameter.KEYWORD_ONLY
    assert sig.parameters["control_loop"].kind is inspect.Parameter.KEYWORD_ONLY


def test_session_surface():
    for name, params in EXPECTED_SESSION_METHODS.items():
        method = inspect.getattr_static(timing.TimingSession, name)
        sig = inspect.signature(method)
        assert list(sig.parameters) == params, f"TimingSession.{name}{sig}"
    for prop in ("scheduler", "control_loop"):
        assert isinstance(inspect.getattr_static(timing.TimingSession, prop), property)


def test_timer_node_fields():
    import dataclasses

    fields = [f.name for f in dataclasses.fields(timing.TimerNode)]
    assert fields == ["name", "count", "inclusive", "exclusive", "children"]


def test_timerdb_hierarchy_surface():
    for name, params in {
        "scope": ["self", "name"],
        "scope_handle": ["self", "path"],
        "tree": ["self"],
        "total_seconds": ["self", "prefix"],
        "current_scope": ["self"],
    }.items():
        sig = inspect.signature(inspect.getattr_static(timing.TimerDB, name))
        assert list(sig.parameters) == params, f"TimerDB.{name}{sig}"


def test_scope_handle_slots():
    # the hot-path object stays lean: no instance dict to allocate
    assert timing.ScopeHandle.__slots__ == ("path", "timer", "_tls")
