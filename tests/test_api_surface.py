"""Public-API snapshots of the ``repro.timing`` and ``repro.serving`` facades.

These are the repo's supported surfaces; future PRs must not silently rename,
drop, or re-sign them.  Changing anything below is an API decision — update
this snapshot *and* the README migration table together.
"""

import dataclasses
import inspect

import repro.serving as serving
import repro.timing as timing

EXPECTED_ALL = [
    "ScopeHandle",
    "Timer",
    "TimerDB",
    "TimerNode",
    "TimingSession",
    "counter",
    "current_scope",
    "current_session",
    "format_tree",
    "scope",
    "scope_handle",
    "session",
    "timed",
    "timer_db",
    "total_seconds",
    "tree",
]

# facade callables: exact parameter names, in order
EXPECTED_PARAMS = {
    "scope": ["name", "db"],
    "scope_handle": ["path", "db"],
    "current_scope": ["db"],
    "counter": ["name", "absolute", "db"],
    "timed": ["name", "db"],
    "session": ["db", "kwargs"],
    "current_session": [],
    "tree": ["db"],
    "format_tree": ["db", "prefix", "title"],
    "total_seconds": ["prefix", "db"],
    "timer_db": [],
}

EXPECTED_SESSION_METHODS = {
    "scope": ["self", "name"],
    "scope_handle": ["self", "path"],
    "counter": ["self", "name", "absolute"],
    "timer": ["self", "ref"],
    "tree": ["self"],
    "tree_rows": ["self", "prefix"],
    "total_seconds": ["self", "prefix"],
    "report": ["self", "kwargs"],
    "tree_report": ["self", "kwargs"],
    "snapshot": ["self"],
    "__enter__": ["self"],
    "__exit__": ["self", "exc_type", "exc", "tb"],
}


def test_all_is_frozen():
    assert list(timing.__all__) == EXPECTED_ALL


def test_every_name_importable():
    for name in timing.__all__:
        assert getattr(timing, name, None) is not None, name


def test_facade_signatures():
    for name, params in EXPECTED_PARAMS.items():
        sig = inspect.signature(getattr(timing, name))
        assert list(sig.parameters) == params, f"{name}{sig}"


def test_session_constructor_signature():
    sig = inspect.signature(timing.TimingSession.__init__)
    assert list(sig.parameters) == ["self", "db", "scheduler", "control_loop"]
    # scheduler/control_loop are keyword-only injection points
    assert sig.parameters["scheduler"].kind is inspect.Parameter.KEYWORD_ONLY
    assert sig.parameters["control_loop"].kind is inspect.Parameter.KEYWORD_ONLY


def test_session_surface():
    for name, params in EXPECTED_SESSION_METHODS.items():
        method = inspect.getattr_static(timing.TimingSession, name)
        sig = inspect.signature(method)
        assert list(sig.parameters) == params, f"TimingSession.{name}{sig}"
    for prop in ("scheduler", "control_loop"):
        assert isinstance(inspect.getattr_static(timing.TimingSession, prop), property)


def test_timer_node_fields():
    fields = [f.name for f in dataclasses.fields(timing.TimerNode)]
    assert fields == ["name", "count", "inclusive", "exclusive", "children"]


def test_timerdb_hierarchy_surface():
    for name, params in {
        "scope": ["self", "name"],
        "scope_handle": ["self", "path"],
        "tree": ["self"],
        "total_seconds": ["self", "prefix"],
        "current_scope": ["self"],
    }.items():
        sig = inspect.signature(inspect.getattr_static(timing.TimerDB, name))
        assert list(sig.parameters) == params, f"TimerDB.{name}{sig}"


def test_scope_handle_slots():
    # the hot-path object stays lean: no instance dict to allocate
    assert timing.ScopeHandle.__slots__ == ("path", "timer", "_tls")


def test_pr4_timing_shims_removed():
    # deprecated in PR 4, removed in PR 8: repro.timing is the one blessed
    # path — the flat core sugar must not quietly come back
    import repro.core
    import repro.core.timers

    assert not hasattr(timing.TimerDB, "timing")
    assert not hasattr(repro.core.timers, "timed")
    assert not hasattr(repro.core, "timed")
    assert "timed" not in repro.core.__all__


def test_timerdb_cardinality_surface():
    # the exporter/soak introspection hook added with the shim removal
    sig = inspect.signature(inspect.getattr_static(timing.TimerDB, "cardinality"))
    assert list(sig.parameters) == ["self"]


# --- repro.serving (PR 6 API redesign: continuous batching) -------------------

EXPECTED_SERVING_ALL = [
    "KVCacheManager",
    "Request",
    "RequestHandle",
    "RequestResult",
    "ServeSession",
    "ServiceLevel",
]

EXPECTED_SERVE_SESSION_METHODS = {
    "__init__": [
        "self", "cfg", "params", "session", "n_slots", "max_seq",
        "block_size", "slo", "db", "registry", "control",
    ],
    "submit": ["self", "request"],
    "shed": ["self", "n"],
    "step": ["self"],
    "run_until_idle": ["self", "max_steps"],
    "completion_rate": ["self"],
    "stats": ["self"],
    "request_stats": ["self"],
}


def test_serving_all_is_frozen():
    assert list(serving.__all__) == EXPECTED_SERVING_ALL


def test_serving_every_name_importable():
    for name in serving.__all__:
        assert getattr(serving, name, None) is not None, name


def test_serve_session_surface():
    for name, params in EXPECTED_SERVE_SESSION_METHODS.items():
        method = inspect.getattr_static(serving.ServeSession, name)
        sig = inspect.signature(method)
        assert list(sig.parameters) == params, f"ServeSession.{name}{sig}"
    # everything after the model is keyword-only wiring
    init = inspect.signature(serving.ServeSession.__init__)
    for kw in ("session", "n_slots", "max_seq", "block_size", "slo", "db",
               "registry", "control"):
        assert init.parameters[kw].kind is inspect.Parameter.KEYWORD_ONLY, kw
    for prop in ("queue_depth", "active_slots", "max_active", "control_loop"):
        assert isinstance(inspect.getattr_static(serving.ServeSession, prop), property)


def test_request_handle_surface():
    # submit() returns a lean future-like handle: done is non-blocking, result
    # drives the engine; no instance dict
    assert isinstance(inspect.getattr_static(serving.RequestHandle, "done"), property)
    assert list(inspect.signature(serving.RequestHandle.result).parameters) == ["self"]
    assert serving.RequestHandle.__slots__ == (
        "request", "_engine", "_result", "_submitted_at", "_admitted_at",
        "_first_token_at", "_tokens", "_truncated", "_slot",
    )


def test_request_and_result_fields():
    fields = [f.name for f in dataclasses.fields(serving.Request)]
    assert fields == ["rid", "prompt", "max_new_tokens", "eos_token"]
    fields = [f.name for f in dataclasses.fields(serving.RequestResult)]
    assert fields == [
        "rid", "tokens", "status", "submitted_at", "finished_at",
        "admitted_at", "first_token_at", "prompt_len", "truncated",
    ]


def test_service_level_fields():
    fields = [f.name for f in dataclasses.fields(serving.ServiceLevel)]
    assert fields == ["target_decode_ms", "max_queue_delay_s", "grow_headroom", "shed_from"]


def test_kv_cache_manager_signature():
    sig = inspect.signature(serving.KVCacheManager.__init__)
    assert list(sig.parameters) == ["self", "cfg", "n_slots", "max_seq", "block_size", "db"]
