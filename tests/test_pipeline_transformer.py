"""The real model through the 1F1B pipeline: token/grad identity against the
non-pipelined ``models.model.loss_fn`` reference with the Pallas kernels
(flash attention, fused rmsnorm, rglru scan, wkv6) active inside the staged
computation.  Tier-1 runs the single-stage schedule on the default 1-device
pod mesh (the tick clock, hook wiring, and kernel dispatch are all live);
the real multi-stage ring — including an uneven restaged plan with padded
slots — runs on a forced 4-device topology in the nightly subprocess test.

seq_len is 128 everywhere: flash attention silently falls back to the
chunked reference when ``s % 128 != 0``, and the point here is the real
Pallas interpret path.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.dist.meshutil import local_mesh
from repro.dist.pipeline import PipelineStep, StagePlan
from repro.models import model as M, pipeline as MP
from repro.models.config import ArchConfig, MoESettings

SEQ = 128
BATCH = 4

_BASE = dict(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=96,
    dtype="float32", norm_impl="fused", remat="none",
)


def _dense_cfg(**kw):
    return ArchConfig(
        name="pipe-dense", family="dense", attn_impl="pallas",
        **{**_BASE, "tied_embeddings": True, **kw},
    )


def _hybrid_cfg(**kw):
    return ArchConfig(
        name="pipe-hybrid", family="hybrid", attn_impl="pallas",
        block_pattern=("rglru", "attn_local", "attn_local"), window=64,
        tied_embeddings=True, **{**_BASE, **{"n_layers": 6, **kw}},
    )


def _rwkv_cfg(**kw):
    return ArchConfig(
        name="pipe-rwkv", family="ssm", block_pattern=("rwkv",),
        rwkv_head_dim=16, n_kv_heads=4, tied_embeddings=False,
        **{**{k: v for k, v in _BASE.items() if k != "n_kv_heads"}, **kw},
    )


def _check(cfg, *, n_micro=2, plan=None, tol=1e-5):
    """Pipeline loss + merged grads vs the fused non-pipelined reference."""
    n_units = MP.check_pipelineable(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    kt, kg = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(kt, (BATCH, SEQ), 0, cfg.vocab_size)
    targets = jax.random.randint(kg, (BATCH, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": targets}
    ref_loss, ref_grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch)[0]
    )(params)

    mesh = local_mesh((1,), ("pod",))
    plan = plan or StagePlan.equal(range(1), n_units)
    layer_fn, first_fn, last_fn = MP.make_stage_fns(cfg)
    step = PipelineStep(
        layer_fn, None, mesh=mesh, axis="pod", n_micro=n_micro,
        first_fn=first_fn, last_fn=last_fn,
    )
    stack, first, last = MP.split_params(cfg, params)
    packed, mask = plan.pack(stack)
    loss, (pg, fg, lg) = step(
        packed, tokens, targets, stage_mask=mask,
        first_params=first, last_params=last,
    )
    grads = MP.merge_grads(cfg, plan.unpack(pg), fg, lg)

    assert abs(float(loss - ref_loss)) < tol, (float(loss), float(ref_loss))
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), grads, ref_grads
    )
    worst = max(
        jax.tree_util.tree_leaves_with_path(errs), key=lambda kv: kv[1]
    )
    assert worst[1] < tol, (
        f"max grad diff {worst[1]:.3e} at {jax.tree_util.keystr(worst[0])}"
    )


def test_dense_attn_pipeline_matches_reference():
    """Flash attention (Pallas interpret) + fused rmsnorm, tied embeddings:
    the embed table's two gradient contributions (first-stage gather,
    last-stage matmul) must re-merge to the reference grad."""
    _check(_dense_cfg())


def test_hybrid_rglru_pipeline_matches_reference():
    """One pattern period (rglru + 2x local attention) per pipeline slot."""
    _check(_hybrid_cfg(), n_micro=2)


@pytest.mark.slow
def test_rwkv6_pipeline_matches_reference():
    """wkv6 recurrence per slot, untied head (lm_head grads flow through the
    last-stage hook only).  Nightly: the chunked wkv6 vjp dominates."""
    _check(_rwkv_cfg(), tol=2e-5)


def test_check_pipelineable_rejections():
    with pytest.raises(ValueError):  # vlm family
        MP.check_pipelineable(_dense_cfg().replace(family="vlm"))
    with pytest.raises(ValueError):  # MoE aux loss not plumbed
        MP.check_pipelineable(
            _dense_cfg().replace(
                family="moe",
                moe=MoESettings(n_experts=4, top_k=2, d_expert=64),
            )
        )
    with pytest.raises(ValueError):  # pattern does not divide n_layers
        MP.check_pipelineable(_hybrid_cfg().replace(n_layers=7))
    assert MP.check_pipelineable(_hybrid_cfg()) == 2


def test_split_merge_round_trip_preserves_structure():
    cfg = _dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    stack, first, last = MP.split_params(cfg, params)
    assert "embed" in first and "embed" in last  # tied: table rides along
    merged = MP.merge_grads(
        cfg, stack,
        jax.tree.map(jnp.zeros_like, first),
        jax.tree.map(jnp.zeros_like, last),
    )
    ref = jax.tree.structure(params)
    assert jax.tree.structure(merged) == ref

    cfg_u = _dense_cfg(tied_embeddings=False)
    params_u = M.init_params(cfg_u, jax.random.PRNGKey(3))
    stack, first, last = MP.split_params(cfg_u, params_u)
    assert "lm_head" in last and "embed" not in last
    merged = MP.merge_grads(cfg_u, stack, first, last)
    assert jax.tree.structure(merged) == jax.tree.structure(params_u)


def test_train_launcher_pipeline_model_path():
    """--pipeline-model end to end: the launcher reports the transformer as
    the pipelined workload and the per-phase scopes get timed."""
    from repro.core.timers import TimerDB
    from repro.launch.train import TrainSettings, run_training
    from repro.timing import TimingSession

    settings = TrainSettings(
        steps=2, global_batch=4, seq_len=32, ckpt_dir=None, ckpt_mode="off",
        report_every=0, pipeline_stages=1, pipeline_micro=2,
        pipeline_model=True,
    )
    sess = TimingSession(TimerDB())
    summary = run_training(settings, session=sess)
    assert summary["iterations"] == 2
    pipe = summary["pipeline"]
    assert pipe["workload"] != "mlp"
    loss = summary["final_metrics"]["loss"]
    assert loss == loss and loss >= 0.0
    for phase in ("warmup", "steady", "cooldown"):
        assert sess.db.get(f"train/pipeline/{phase}").count == settings.steps


# ---------------------------------------------------------------------------
# Real multi-stage ring (forced 4-device topology, nightly tier)
# ---------------------------------------------------------------------------

MULTIDEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp

from repro.dist.meshutil import local_mesh
from repro.dist.pipeline import PipelineStep, StagePlan
from repro.models import model as M, pipeline as MP
from repro.models.config import ArchConfig

cfg = ArchConfig(
    name="pipe-md", family="dense", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=96, dtype="float32",
    attn_impl="pallas", norm_impl="fused", tied_embeddings=False,
    remat="none",
)
n_units = MP.check_pipelineable(cfg)
mesh = local_mesh((4,), ("pod",))
params = M.init_params(cfg, jax.random.PRNGKey(0))
kt, kg = jax.random.split(jax.random.PRNGKey(1))
tokens = jax.random.randint(kt, (6, 128), 0, cfg.vocab_size)
targets = jax.random.randint(kg, (6, 128), 0, cfg.vocab_size)
batch = {"tokens": tokens, "targets": targets}
ref_loss, ref_grads = jax.value_and_grad(
    lambda p: M.loss_fn(cfg, p, batch)[0]
)(params)

layer_fn, first_fn, last_fn = MP.make_stage_fns(cfg)
step = PipelineStep(layer_fn, None, mesh=mesh, axis="pod", n_micro=3,
                    first_fn=first_fn, last_fn=last_fn)
stack, first, last = MP.split_params(cfg, params)

for plan in (
    StagePlan.equal(range(4), n_units),
    StagePlan(n_layers=n_units, weights={0: 2.0, 1: 1.0, 2: 1.0, 3: 1.0}),
):
    packed, mask = plan.pack(stack)
    loss, (pg, fg, lg) = step(packed, tokens, targets, stage_mask=mask,
                              first_params=first, last_params=last)
    grads = MP.merge_grads(cfg, plan.unpack(pg), fg, lg)
    assert abs(float(loss - ref_loss)) < 1e-5, (float(loss), float(ref_loss))
    gd = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                      grads, ref_grads)
    worst = max(jax.tree_util.tree_leaves(gd))
    assert worst < 1e-5, worst
print("PIPELINE_TRANSFORMER_MULTIDEVICE_OK")
"""


@pytest.mark.multihost
@pytest.mark.slow
def test_transformer_pipeline_on_real_devices_subprocess():
    """Grad identity across a real 4-rank ppermute ring with embed/head
    pinned to first/last stages, even and restaged-uneven stage splits."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEVICE_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env, cwd=repo,
    )
    assert proc.returncode == 0, proc.stderr
    assert "PIPELINE_TRANSFORMER_MULTIDEVICE_OK" in proc.stdout
